"""EXPLAIN for compiled window plans: structure without execution.

``explain_session(session)`` (surfaced as :meth:`Session.explain`) walks a
live :class:`~repro.core.api.Session` and returns a :class:`PlanReport`
answering the *why* questions the metric counters cannot:

* **engine resolution** — which capability won each plan group, and why
  every other registered capability lost (window kind not served,
  aggregates not covered, sharded-flag mismatch, or simply lower
  priority);
* **lowering choice** — per (expression, monoid set): direct leaf
  materialization, generic composite materialization (with the exact
  planner reason the algebraic fast path was rejected), idempotent
  combine, or pairwise inclusion–exclusion (with the rejected alternative
  named);
* **plan anatomy** — per materialized term: blocks, tile groups, ELL
  layouts, headroom utilization (real vs padded rows), garbage fraction,
  and shard layout balance for :class:`ShardedDBPlan`;
* **memory footprint** — exact per-array device bytes via the plan
  classes' ``array_nbytes()`` / ``plan_nbytes()`` (the accounting ROADMAP
  direction 2's out-of-core spilling blocks on).

Everything here is read-only introspection of host metadata: no jitted
function is called, no device computation launched, so EXPLAIN can never
perturb the zero-recompile or bit-identity invariants it reports on.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["PlanReport", "GroupReport", "TermReport", "explain_session"]


# ---------------------------------------------------------------------- #
#  Report dataclasses
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class TermReport:
    """Anatomy + footprint of one materialized term (index, plan) pair."""

    window: str
    index_kind: Optional[str]  # dbindex | iindex | eagr | None (stateless)
    index: Dict  # host index anatomy
    plan_kind: Optional[str]  # DBIndexPlan | IIndexPlan | ShardedDBPlan | None
    plan: Dict  # device plan anatomy
    array_nbytes: Dict  # name -> exact device bytes
    plan_nbytes: int  # sum of the above
    state: Dict  # streaming-state telemetry (version, staleness, reorgs)

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class GroupReport:
    """One fused plan group: resolution, lowering, and its terms."""

    window: str
    window_kind: str
    attr: str
    aggs: Tuple[str, ...]
    engine: str
    capability: Dict
    candidates: List[Dict]  # every registered capability + accept/reject
    lowering: Dict  # choice, reason, rejected alternatives
    terms: List[TermReport]
    group_nbytes: int

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["terms"] = [t.to_dict() for t in self.terms]
        return d


@dataclasses.dataclass
class PlanReport:
    """The full EXPLAIN output for one session."""

    n_vertices: int
    n_edges: int
    version: int
    sharded: bool
    groups: List[GroupReport]
    total_plan_nbytes: int

    def to_dict(self) -> Dict:
        return {
            "n_vertices": self.n_vertices,
            "n_edges": self.n_edges,
            "version": self.version,
            "sharded": self.sharded,
            "total_plan_nbytes": self.total_plan_nbytes,
            "groups": [g.to_dict() for g in self.groups],
        }

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True, **kw)

    # ------------------------------------------------------------------ #
    def text(self) -> str:
        """Human-readable rendering (the ``EXPLAIN`` console view)."""
        L: List[str] = []
        L.append(f"Session: n={self.n_vertices} vertices, m={self.n_edges} "
                 f"edges, version={self.version}, sharded={self.sharded}")
        L.append(f"Total device plan footprint: "
                 f"{_fmt_bytes(self.total_plan_nbytes)}")
        for gi, g in enumerate(self.groups):
            L.append("")
            L.append(f"Group {gi}: {g.window} [{g.window_kind}] "
                     f"attr={g.attr!r} aggs={list(g.aggs)}")
            L.append(f"  engine: {g.engine} (priority "
                     f"{g.capability.get('priority')})")
            for c in g.candidates:
                if c["name"] == g.engine:
                    continue
                L.append(f"    rejected {c['name']}: {c['reason']}")
            low = g.lowering
            L.append(f"  lowering: {low['choice']} — {low['reason']}")
            for alt in low.get("rejected", ()):
                L.append(f"    rejected {alt['choice']}: {alt['reason']}")
            for t in g.terms:
                L.append(f"  term {t.window}: index={t.index_kind} "
                         f"plan={t.plan_kind} "
                         f"footprint={_fmt_bytes(t.plan_nbytes)}")
                for k, v in sorted(t.index.items()):
                    L.append(f"    index.{k}: {v}")
                for k, v in sorted(t.plan.items()):
                    L.append(f"    plan.{k}: {v}")
                for k, v in sorted(t.array_nbytes.items()):
                    L.append(f"    bytes.{k}: {v}")
                if t.state:
                    L.append(f"    state: {t.state}")
            L.append(f"  group footprint: {_fmt_bytes(g.group_nbytes)}")
        return "\n".join(L)


def _fmt_bytes(nb: int) -> str:
    x = float(nb)
    for unit in ("B", "KiB", "MiB", "GiB"):
        if x < 1024 or unit == "GiB":
            return f"{x:.1f} {unit}" if unit != "B" else f"{int(x)} B"
        x /= 1024
    return f"{int(nb)} B"


# ---------------------------------------------------------------------- #
#  Engine resolution
# ---------------------------------------------------------------------- #
def _candidate_rows(session, grp) -> List[Dict]:
    """Accept/reject verdict for every registered capability against this
    group's (window, aggs) — re-deriving what ``EngineRegistry.select``
    saw, with the winner marked and every loser given a concrete reason."""
    from repro.core.api import window_kind

    chosen = session.registry.capability(grp.engine)
    kind = window_kind(grp.window)
    aggset = set(grp.aggs)
    rows = []
    for cap in session.registry.capabilities():
        row = {
            "name": cap.name,
            "priority": cap.priority,
            "windows": list(cap.windows),
            "device": cap.device,
            "sharded": cap.sharded,
            "incremental": cap.incremental,
        }
        if cap.name == chosen.name:
            row["selected"] = True
            row["reason"] = "selected (highest-priority cover)"
        elif kind not in cap.windows:
            row["selected"] = False
            row["reason"] = (f"window kind {kind!r} not served "
                             f"(serves {list(cap.windows)})")
        elif not aggset <= cap.aggregates:
            missing = sorted(aggset - set(cap.aggregates))
            row["selected"] = False
            row["reason"] = f"aggregates not covered: {missing}"
        elif cap.sharded != chosen.sharded:
            row["selected"] = False
            row["reason"] = ("requires a device mesh (sharded)"
                             if cap.sharded else
                             "not sharded — session runs on a mesh")
        elif cap.priority < chosen.priority:
            row["selected"] = False
            row["reason"] = (f"covers the query but priority "
                             f"{cap.priority} < {chosen.priority}")
        else:
            row["selected"] = False
            row["reason"] = "covers the query; not selected (explicit pin)"
        rows.append(row)
    return rows


# ---------------------------------------------------------------------- #
#  Lowering choice
# ---------------------------------------------------------------------- #
def _lowering_report(session, gi: int) -> Dict:
    """The per-(expression, monoid set) lowering decision, re-deriving the
    planner's rejection reason when the algebraic fast path was skipped."""
    from repro.core.api import (
        CHANNEL_AGG,
        Union,
        _group_channels,
        _kind_of,
        window_kind,
    )

    grp = session.compiled.groups[gi]
    prog = session._programs[gi]
    kind = window_kind(grp.window)
    if prog is not None:
        incl_excl = any(c == -1 for c in prog.sum_coefs)
        choice = ("inclusion-exclusion" if incl_excl
                  else "idempotent-combine")
        rep = {
            "choice": choice,
            "reason": (
                "sum-monoid channels ride Σ(A∪B) = Σ(A) + Σ(B) − Σ(A∩B); "
                "only the intersection is extra-materialized"
                if incl_excl else
                "all requested channels are idempotent monoids — pointwise "
                "combine over the children's materializations"
            ),
            "terms": [t.name() for t in prog.terms],
            "term_aggs": list(prog.term_aggs),
            "sum_coefs": list(prog.sum_coefs),
            "rejected": [{
                "choice": "generic-materialization",
                "reason": "algebraic fast path available — avoids "
                          "materializing the composite's window sets",
            }],
        }
        if incl_excl:
            rep["rejected"].append({
                "choice": "idempotent-combine",
                "reason": "a sum-monoid channel is requested; union "
                          "cardinalities overlap, so pointwise combine "
                          "would double-count",
            })
        return rep
    # prog is None — reconstruct why plan_window_program declined
    if kind != "composite":
        return {
            "choice": "direct",
            "reason": f"leaf window ({kind}) — materialized directly by "
                      f"the {grp.engine!r} runner",
            "terms": [grp.window.name()],
            "rejected": [],
        }
    if _kind_of(grp.engine) != "dbindex":
        reason = (f"engine {grp.engine!r} is not dbindex-backed; algebraic "
                  f"programs lower only onto dbindex materializations")
    elif not isinstance(grp.window, Union):
        reason = ("composite is not a Union — only unions admit an "
                  "algebraic decomposition (idempotent combine / "
                  "inclusion–exclusion)")
    else:
        channels = _group_channels(grp.aggs)
        bad = [ch for ch in channels if ch not in CHANNEL_AGG]
        has_sum = any(m == "sum" for m, _ in channels)
        if bad:
            reason = (f"channel(s) {bad} have no canonical per-term "
                      f"aggregate")
        elif has_sum and len(grp.window.exprs) != 2:
            reason = (f"union has {len(grp.window.exprs)} children with a "
                      f"sum-monoid channel; inclusion–exclusion is kept "
                      f"pairwise (2^n terms otherwise)")
        else:  # defensive: mirrors plan_window_program returning a program
            reason = "planner declined (unrecognized shape)"
    return {
        "choice": "generic-materialization",
        "reason": reason,
        "terms": [grp.window.name()],
        "rejected": [{
            "choice": "algebraic-program",
            "reason": reason,
        }],
    }


# ---------------------------------------------------------------------- #
#  Plan anatomy + footprint
# ---------------------------------------------------------------------- #
def _index_anatomy(index) -> Tuple[Optional[str], Dict]:
    if index is None:
        return None, {}
    cls = type(index).__name__
    if cls == "DBIndex":
        from repro.core.streaming import garbage_block_fraction

        sizes = np.diff(index.block_offsets)
        return "dbindex", {
            "n": int(index.n),
            "num_blocks": int(index.num_blocks),
            "member_rows": int(index.block_members.size),
            "link_rows": int(index.link_block.size),
            "mean_block_size": (float(sizes.mean()) if sizes.size else 0.0),
            "max_block_size": (int(sizes.max()) if sizes.size else 0),
            "garbage_fraction": float(garbage_block_fraction(index)),
        }
    if cls == "IIndex":
        return "iindex", {
            "n": int(index.n),
            "wd_rows": int(index.wd_members.size),
            "max_level": (int(index.level.max()) if index.n else 0),
        }
    return cls.lower(), {"type": cls}


def _plan_anatomy(plan, index) -> Tuple[Optional[str], Dict, Dict]:
    """(plan_kind, anatomy, array_nbytes) for any of the three plan classes
    (or a host-only/stateless term with no device plan)."""
    if plan is None:
        return None, {}, {}
    cls = type(plan).__name__
    if cls == "DBIndexPlan":
        real1 = int(index.block_members.size) if index is not None else None
        real2 = int(index.link_block.size) if index is not None else None
        pad1 = int(plan.pass1.gather_padded.size)
        pad2 = int(plan.pass2.gather_padded.size)
        anat = {
            "num_blocks": int(plan.num_blocks),
            "block_capacity": int(plan.block_capacity),
            "capacity_utilization": plan.num_blocks / plan.block_capacity,
            "pass1_rows_padded": pad1,
            "pass2_rows_padded": pad2,
            "pass1_tile_groups": int(plan.pass1.num_out_tiles),
            "pass2_tile_groups": int(plan.pass2.num_out_tiles),
            "tile": {"tm": int(plan.pass1.tm), "ts": int(plan.pass1.ts)},
            "ell": {
                "p1_width": (int(plan.p1_ell.shape[1])
                             if plan.p1_ell is not None else None),
                "p2_width": (int(plan.p2_ell.shape[1])
                             if plan.p2_ell is not None else None),
            },
        }
        if real1 is not None:
            anat["pass1_rows_real"] = real1
            anat["pass1_headroom_utilization"] = real1 / max(pad1, 1)
        if real2 is not None:
            anat["pass2_rows_real"] = real2
            anat["pass2_headroom_utilization"] = real2 / max(pad2, 1)
        return cls, anat, plan.array_nbytes()
    if cls == "IIndexPlan":
        real = int(index.wd_members.size) if index is not None else None
        pad = int(plan.wd_plan.gather_padded.size)
        anat = {
            "max_level": int(plan.max_level),
            "wd_rows_padded": pad,
            "wd_tile_groups": int(plan.wd_plan.num_out_tiles),
            "tile": {"tm": int(plan.wd_plan.tm), "ts": int(plan.wd_plan.ts)},
        }
        if real is not None:
            anat["wd_rows_real"] = real
            anat["wd_headroom_utilization"] = real / max(pad, 1)
        return cls, anat, plan.array_nbytes()
    if cls == "ShardedDBPlan":
        anat = {
            "ndev": int(plan.ndev),
            "num_blocks": int(plan.num_blocks),
            "block_capacity": int(plan.block_capacity),
            "capacity_utilization": plan.num_blocks / plan.block_capacity,
            "rows1_per_shard": int(plan.rows1),
            "rows2_per_shard": int(plan.rows2),
            "has_ell": bool(plan.has_ell),
            "shard_balance": plan.shard_row_loads(),
            "patch_ledger": {
                k: plan.stats[k]
                for k in ("version", "patched_bytes_total", "rebuilds",
                          "full_bytes")
                if k in plan.stats
            },
        }
        return cls, anat, plan.array_nbytes()
    # unknown plan type: still account what we can
    nb = {}
    if hasattr(plan, "array_nbytes"):
        nb = plan.array_nbytes()
    return cls, {"type": cls}, nb


def _state_telemetry(session, term, kind) -> Dict:
    state = session._states.get((term, kind)) if kind else None
    if state is None:
        return {}
    out = {}
    pv = getattr(state, "plan_version", None)
    if pv is None and getattr(state, "plan", None) is not None:
        pv = getattr(state.plan, "stats", {}).get("version")
    if pv is not None:
        out["plan_version"] = int(pv)
    if hasattr(state, "reorg_count"):
        out["reorg_count"] = int(state.reorg_count)
    try:
        out["staleness"] = {k: float(v)
                            for k, v in state.staleness.items()}
    except Exception:
        pass
    return out


# ---------------------------------------------------------------------- #
def _match_groups(session, spec) -> List[int]:
    """Group indices selected by ``spec``: None → all; an int → that group;
    a QuerySpec / window spec → the groups serving it."""
    n = len(session.compiled.groups)
    if spec is None:
        return list(range(n))
    if isinstance(spec, int):
        if not 0 <= spec < n:
            raise IndexError(f"group {spec} out of range (have {n})")
        return [spec]
    from repro.core.api import QuerySpec, as_window

    if isinstance(spec, QuerySpec):
        window, agg = spec.window, spec.agg
    else:
        window, agg = as_window(spec), None
    out = [
        gi for gi, grp in enumerate(session.compiled.groups)
        if grp.window == window and (agg is None or agg in grp.aggs)
    ]
    if not out:
        raise KeyError(f"no compiled group serves {spec!r}")
    return out


def explain_session(session, spec=None) -> PlanReport:
    """Build the :class:`PlanReport` for ``session`` (no execution).

    ``spec`` filters: ``None`` explains every compiled group; an ``int``
    selects one group by index; a :class:`QuerySpec` or window spec
    selects the group(s) serving that window.
    """
    from repro.core.api import _kind_of, window_kind

    groups: List[GroupReport] = []
    total = 0
    for gi in _match_groups(session, spec):
        grp = session.compiled.groups[gi]
        kind = _kind_of(grp.engine)
        cap = session.registry.capability(grp.engine)
        terms: List[TermReport] = []
        gbytes = 0
        arts = session._group_artifacts(gi)
        for term, (index, plan) in zip(session._group_terms(gi), arts):
            ikind, ianat = _index_anatomy(index)
            pkind, panat, nb = _plan_anatomy(plan, index)
            pbytes = sum(nb.values())
            gbytes += pbytes
            terms.append(TermReport(
                window=term.name(),
                index_kind=ikind,
                index=ianat,
                plan_kind=pkind,
                plan=panat,
                array_nbytes=nb,
                plan_nbytes=pbytes,
                state=_state_telemetry(session, term, kind),
            ))
        groups.append(GroupReport(
            window=grp.window.name(),
            window_kind=window_kind(grp.window),
            attr=grp.attr,
            aggs=tuple(grp.aggs),
            engine=grp.engine,
            capability={
                "name": cap.name, "priority": cap.priority,
                "windows": list(cap.windows), "device": cap.device,
                "sharded": cap.sharded, "incremental": cap.incremental,
            },
            candidates=_candidate_rows(session, grp),
            lowering=_lowering_report(session, gi),
            terms=terms,
            group_nbytes=gbytes,
        ))
        total += gbytes
    g = session.graph
    return PlanReport(
        n_vertices=int(g.n),
        n_edges=int(np.asarray(g.src).size),
        version=int(session.version),
        sharded=bool(session._sharded),
        groups=groups,
        total_plan_nbytes=total,
    )
