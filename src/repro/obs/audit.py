"""Online correctness auditing: shadow oracle, content digests, WAL scrub.

The repo's correctness backbone — every engine bit-identical to the
set-evaluation oracle — is asserted by tests but, until this module, never
*observed* in the running system.  Three independent evidence channels
turn it into production telemetry:

* :class:`ShadowAuditor` — samples a configurable fraction of served
  tickets (plus a trickle of rows from full-graph results), re-evaluates
  each sample **asynchronously** on a background thread against the
  independent per-vertex set-evaluation oracle (:func:`oracle_single`,
  the same math as ``repro.core.query.brute_force`` restricted to one
  vertex) *at the pinned snapshot version* — MVCC makes the replay
  well-defined: the sample captures the immutable graph the view served
  from, so the oracle sees exactly what the engine saw.  Comparison is
  bitwise; a mismatch quarantines an :class:`AuditFinding`, increments
  ``repro_audit_mismatches_total`` and lands a flight-recorder event.

* **Digest channel** — :func:`session_digest` folds cheap crc32 content
  digests over the graph arrays, every plan array (enumerated through the
  same ``array_nbytes()`` surface EXPLAIN's byte accounting uses) and
  optionally the full result vectors.  The leader stamps one digest into
  the WAL after every published version
  (:meth:`repro.serve.wal.WriteAheadLog.append_digest`) and into sharded
  patch wire messages, so a follower self-checks after every poll and
  attributes divergence to the **first bad version + WAL byte offset**.

* :class:`WalScrubber` — background sweep of the *sealed* log region
  (records wholly below the WAL's fsync high-water mark) re-verifying
  every record CRC independent of replay, so at-rest corruption ("CRC
  rot") is found proactively instead of at the next crash recovery.

All three feed :class:`repro.serve.health.HealthMonitor`: any quarantined
finding flips readiness.

Sampling never blocks serving: the auditor's queue is bounded and
``put_nowait`` drops (counted in ``repro_audit_dropped_total``) rather
than waiting, and capture is O(1) references to immutable snapshot state.

Bitwise comparison leans on the repo invariant that holds everywhere the
suite asserts it: integer-valued attributes make every f32 partial exact,
so engine evaluation order is irrelevant and the finalizer is the only
rounding step on both sides.  For float workloads outside that contract,
construct the auditor with a ``tolerance`` to compare within an absolute
bound instead.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs as _obs
from repro.core.aggregates import AGGREGATES
from repro.core.windows import expr_window_single

__all__ = [
    "AuditFinding", "ShadowAuditor", "WalScrubber",
    "oracle_single", "named_plan_arrays", "plan_crc", "graph_crc",
    "session_digest", "digests_match",
]


# ---------------------------------------------------------------------- #
#  Content digests (crc32, order-stable)
# ---------------------------------------------------------------------- #
def _crc_bytes(crc: int, b: bytes) -> int:
    return zlib.crc32(b, crc) & 0xFFFFFFFF


def _crc_array(crc: int, a) -> int:
    """Fold one array into ``crc``: dtype + shape + raw bytes, so a shape
    or dtype drift is as detectable as a value drift."""
    a = np.asarray(a)
    crc = _crc_bytes(crc, str(a.dtype).encode())
    crc = _crc_bytes(crc, repr(a.shape).encode())
    return _crc_bytes(crc, np.ascontiguousarray(a).tobytes())


def named_plan_arrays(plan) -> Dict[str, object]:
    """The named arrays a plan holds, resolved through the same key scheme
    as ``plan.array_nbytes()`` (keys are dotted attribute paths — this is
    the PR-8 byte-accounting enumeration reused as a content surface, so
    the digest provably covers every array the footprint report counts)."""
    out = {}
    for key in plan.array_nbytes():
        obj = plan
        for part in key.split("."):
            obj = getattr(obj, part)
        out[key] = obj
    return out


def plan_crc(plan, crc: int = 0) -> int:
    """crc32 over every array of one plan, in sorted key order."""
    arrays = named_plan_arrays(plan)
    for key in sorted(arrays):
        crc = _crc_bytes(crc, key.encode())
        crc = _crc_array(crc, arrays[key])
    return crc


def graph_crc(graph, crc: int = 0) -> int:
    """crc32 over the graph's structural arrays + every attribute."""
    crc = _crc_bytes(crc, f"n={graph.n};directed={graph.directed}".encode())
    crc = _crc_array(crc, graph.src)
    crc = _crc_array(crc, graph.dst)
    for name in sorted(graph.attrs):
        crc = _crc_bytes(crc, name.encode())
        crc = _crc_array(crc, graph.attrs[name])
    return crc


def session_digest(session, include_results: bool = False) -> Dict:
    """Per-version content digest of a :class:`~repro.core.api.Session`.

    Always covers the graph and every live plan; ``include_results=True``
    additionally runs every compiled group once (through the ordinary
    cache-aware snapshot read path — warm executors, no recompiles) and
    folds the result vectors in, turning the digest into an end-to-end
    served-bytes check at the cost of one fused launch per cold group.
    """
    d: Dict = {"version": int(session.version),
               "graph_crc": graph_crc(session.graph)}
    crc = 0
    for (window, kind) in sorted(session._states,
                                 key=lambda k: f"{k[0].name()}/{k[1]}"):
        eng = session._states[(window, kind)]
        crc = _crc_bytes(crc, f"{window.name()}/{kind}".encode())
        if getattr(eng, "plan", None) is not None:
            crc = plan_crc(eng.plan, crc)
    d["plan_crc"] = crc
    if include_results:
        view = session.snapshot()
        crc = 0
        for gi in range(len(session.compiled.groups)):
            out = view.run_group(gi)
            for agg in sorted(out):
                crc = _crc_bytes(crc, f"{gi}:{agg}".encode())
                crc = _crc_array(crc, out[agg])
        d["result_crc"] = crc
    return d


def digests_match(leader: Dict, follower: Dict,
                  check_plans: bool = True) -> Tuple[bool, str]:
    """Compare two session digests component-wise.

    Returns ``(ok, detail)``; only components present on *both* sides are
    compared (a leader that skipped result digests does not fail a
    follower that computed them).  ``check_plans=False`` skips the plan
    component — a replica deliberately running a different engine/layout
    configuration has legitimately different plan bytes while graph and
    result digests must still agree (the bit-identity invariant).
    """
    keys = ["graph_crc", "result_crc"] + (["plan_crc"] if check_plans else [])
    for k in keys:
        if k in leader and k in follower and leader[k] != follower[k]:
            return False, (f"{k}: leader={leader[k]:#010x} "
                           f"follower={follower[k]:#010x}")
    return True, "ok"


# ---------------------------------------------------------------------- #
#  Quarantined findings
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class AuditFinding:
    """One piece of correctness evidence, quarantined for a human.

    ``source`` says which channel raised it: ``"oracle"`` (shadow
    re-evaluation mismatch), ``"scrub"`` (at-rest WAL CRC failure) or
    ``"digest"`` (leader/follower content-digest divergence).  ``expected``
    / ``got`` hold the raw bytes compared (oracle findings); ``version``
    and ``wal_offset`` attribute the damage (scrub/digest findings carry
    the exact record byte offset in the log).
    """

    source: str
    version: Optional[int] = None
    spec: Optional[str] = None
    vertex: Optional[int] = None
    expected: Optional[bytes] = None
    got: Optional[bytes] = None
    wal_offset: Optional[int] = None
    detail: str = ""
    t_unix_s: float = dataclasses.field(default_factory=time.time)

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        for k in ("expected", "got"):
            if d[k] is not None:
                d[k] = d[k].hex()
        return d


# ---------------------------------------------------------------------- #
#  Independent single-vertex oracle
# ---------------------------------------------------------------------- #
def oracle_single(graph, window, values, agg: str, vertex: int, dtype=None):
    """Set-evaluate one vertex's window aggregate — the reference path.

    Same math as :func:`repro.core.query.brute_force` restricted to one
    vertex: frontier BFS / NumPy set ops for the member set
    (:func:`~repro.core.windows.expr_window_single` handles leaves and
    combinators alike), then a direct monoid reduce and the registered
    finalizer.  ``dtype`` pins the channel dtype — pass the *served*
    result's dtype so the comparison is bitwise on integer-valued
    attributes (f32 partials are exact integers on both sides).
    """
    a = AGGREGATES[agg]
    chans = a.prepare(np.asarray(values))
    if dtype is not None:
        chans = tuple(c.astype(dtype) for c in chans)
    w = expr_window_single(graph, window, int(vertex))
    outs = [
        np.asarray(m.np_op.reduce(c[w]) if w.size else m.identity_for(c.dtype),
                   dtype=c.dtype)
        for m, c in zip(a.monoids, chans)
    ]
    return a.finalize_np(*outs)


# ---------------------------------------------------------------------- #
#  ShadowAuditor
# ---------------------------------------------------------------------- #
class ShadowAuditor:
    """Sample served tickets and re-evaluate them against the oracle.

    ``sample_rate`` is the fraction of successfully served point tickets
    audited (deterministic error-diffusion accumulator — an exact rate,
    not a coin flip, so tests and benches are reproducible);
    ``full_row_rate`` is the per-full-graph-result probability of auditing
    one (deterministically rotating) row of the vector.  ``max_queue``
    bounds the hand-off queue; when the worker falls behind, samples are
    **dropped** (never blocking a flush or a ``Ticket.get``).

    Attach with :meth:`repro.serve.window_service.WindowService.
    attach_auditor` (or call :meth:`bind` directly), then :meth:`start`.
    """

    def __init__(self, sample_rate: float = 0.01,
                 full_row_rate: float = 0.05, max_queue: int = 1024,
                 tolerance: Optional[float] = None, obs=None, tracer=None):
        assert 0.0 <= sample_rate <= 1.0
        assert 0.0 <= full_row_rate <= 1.0
        self.sample_rate = float(sample_rate)
        self.full_row_rate = float(full_row_rate)
        self.tolerance = tolerance
        self.obs = obs if obs is not None else _obs.get_registry()
        self.tracer = tracer if tracer is not None else _obs.get_tracer()
        self.service = None  # bound by attach_auditor / bind
        self._q: "queue.Queue" = queue.Queue(maxsize=int(max_queue))
        self._thread: Optional[threading.Thread] = None
        self._stopping = threading.Event()
        self._findings_lock = threading.Lock()
        self.findings: List[AuditFinding] = []
        # deterministic sampling state (observe_flush runs under the
        # service's flush lock, so no extra lock needed)
        self._acc_point = 0.0
        self._acc_full = 0.0
        self._row_seq = 0
        # telemetry
        self.sampled = 0
        self.audited = 0
        self.mismatches = 0
        self.dropped_samples = 0
        self._m_samples = self.obs.counter(
            "repro_audit_samples_total",
            "shadow-audited samples by outcome", labels=("outcome",))
        self._m_mismatch = self.obs.counter(
            "repro_audit_mismatches_total",
            "served results that differ from the set-eval oracle")
        self._m_dropped = self.obs.counter(
            "repro_audit_dropped_total",
            "audit samples dropped on a full queue (never blocks serving)")
        self._h_lag = self.obs.histogram(
            "repro_audit_lag_seconds",
            "serve-to-verdict latency of audited samples")

    # --------------------------- lifecycle ---------------------------- #
    def bind(self, service) -> "ShadowAuditor":
        self.service = service
        return self

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> "ShadowAuditor":
        if not self.running:
            self._stopping.clear()
            self._thread = threading.Thread(
                target=self._worker, name="shadow-auditor", daemon=True)
            self._thread.start()
        return self

    def stop(self, drain: bool = True, timeout: float = 10.0) -> None:
        if drain:
            self.drain(timeout=timeout)
        self._stopping.set()
        if self._thread is not None:
            # unblock the worker's get()
            try:
                self._q.put_nowait(None)
            except queue.Full:
                pass
            self._thread.join(timeout=timeout)
            self._thread = None

    def drain(self, timeout: float = 10.0) -> bool:
        """Block until every queued sample has a verdict (tests/benches);
        returns False on timeout.  Serving never calls this."""
        deadline = time.perf_counter() + timeout
        while self._q.unfinished_tasks:
            if not self.running or time.perf_counter() > deadline:
                return self._q.unfinished_tasks == 0
            time.sleep(0.001)
        return True

    def __enter__(self) -> "ShadowAuditor":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # --------------------------- sampling ----------------------------- #
    def observe_flush(self, view, tickets) -> None:
        """Called by the service after a flush (on the serving thread,
        under its flush lock).  O(1) per sampled ticket: captures
        references to immutable snapshot state and enqueues; evaluation
        happens on the worker."""
        if self.service is None:
            return
        compiled = self.service.session.compiled
        for t in tickets:
            if t.error is not None or t.result is None:
                continue
            if t.vertex is not None:
                self._acc_point += self.sample_rate
                if self._acc_point < 1.0:
                    continue
                self._acc_point -= 1.0
                vertex, served = t.vertex, t.result
            else:
                self._acc_full += self.full_row_rate
                if self._acc_full < 1.0:
                    continue
                self._acc_full -= 1.0
                vec = np.asarray(t.result)
                if vec.size == 0:
                    continue
                # deterministic rotating row pick (no RNG: reproducible)
                self._row_seq += 1
                vertex = int((self._row_seq * 7919) % vec.shape[0])
                served = vec[vertex]
            gi, ai = compiled.spec_slots[t.spec_index]
            grp = compiled.groups[gi]
            values = (t.values if t.values is not None
                      else view.graph.attrs[grp.attr])
            sample = {
                "graph": view.graph,
                "window": grp.window,
                "agg": grp.aggs[ai],
                "attr": grp.attr,
                "values": values,
                "vertex": int(vertex),
                "served": np.asarray(served).copy(),
                "version": t.version,
                "t_served": time.perf_counter(),
            }
            self.sampled += 1
            try:
                self._q.put_nowait(sample)
            except queue.Full:
                self.dropped_samples += 1
                self._m_dropped.inc()

    # --------------------------- verdicts ----------------------------- #
    def _worker(self) -> None:
        self.tracer.name_thread()
        while not self._stopping.is_set():
            try:
                sample = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            try:
                if sample is not None:
                    self._audit_one(sample)
            except Exception:
                # the auditor must never take the process down; an
                # evaluation bug shows up as a missing verdict, not a crash
                pass
            finally:
                self._q.task_done()

    def _audit_one(self, s: Dict) -> None:
        served = np.asarray(s["served"])
        expected = np.asarray(
            oracle_single(s["graph"], s["window"], s["values"], s["agg"],
                          s["vertex"], dtype=served.dtype),
            dtype=served.dtype)
        if self.tolerance is None:
            ok = expected.tobytes() == served.tobytes()
        else:
            ok = bool(abs(float(expected) - float(served)) <= self.tolerance)
        self.audited += 1
        self._m_samples.labels("ok" if ok else "mismatch").inc()
        self._h_lag.observe(time.perf_counter() - s["t_served"])
        if ok:
            return
        spec = f"{s['window'].name()}/{s['agg']}@{s['attr']}"
        finding = AuditFinding(
            source="oracle", version=s["version"], spec=spec,
            vertex=s["vertex"], expected=expected.tobytes(),
            got=served.tobytes(),
            detail=f"oracle={expected!r} served={served!r}")
        self.mismatches += 1
        self._m_mismatch.inc()
        with self._findings_lock:
            self.findings.append(finding)
        svc = self.service
        if svc is not None:
            svc.flight.record(
                "audit", spec=spec, vertex=s["vertex"],
                version=s["version"], expected=expected.tobytes().hex(),
                got=served.tobytes().hex())

    # ------------------------------------------------------------------ #
    @property
    def stats(self) -> Dict:
        return {
            "sample_rate": self.sample_rate,
            "full_row_rate": self.full_row_rate,
            "sampled": self.sampled,
            "audited": self.audited,
            "mismatches": self.mismatches,
            "dropped_samples": self.dropped_samples,
            "queued": self._q.qsize(),
            "running": self.running,
            "findings": [f.to_dict() for f in self.findings],
        }


# ---------------------------------------------------------------------- #
#  WAL scrubber
# ---------------------------------------------------------------------- #
class WalScrubber:
    """Background CRC sweep over the sealed region of a write-ahead log.

    Replay only verifies the log when someone replays it; this sweeps the
    *at-rest* file proactively.  Only records wholly below the durable
    high-water mark are judged (an in-flight/torn tail is a crash
    artifact the WAL already tolerates, never corruption), so a clean run
    has **zero false positives** by construction.  ``wal`` may be a live
    :class:`~repro.serve.wal.WriteAheadLog` (sealed = fsynced bytes) or a
    path (sealed = the whole file — use for closed logs).
    """

    def __init__(self, wal, interval_s: float = 0.25, obs=None,
                 tracer=None, flight=None):
        self.wal = wal
        self.interval_s = float(interval_s)
        self.obs = obs if obs is not None else _obs.get_registry()
        self.tracer = tracer if tracer is not None else _obs.get_tracer()
        self.flight = flight
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._reported: set = set()  # record offsets already quarantined
        self.findings: List[AuditFinding] = []
        self.sweeps = 0
        self.records_verified = 0
        self.corruptions = 0
        self._m_sweeps = self.obs.counter(
            "repro_wal_scrub_sweeps_total", "completed scrub sweeps")
        self._m_records = self.obs.counter(
            "repro_wal_scrub_records_total", "records CRC-verified at rest")
        self._m_corrupt = self.obs.counter(
            "repro_wal_scrub_corruptions_total",
            "sealed records failing their CRC (at-rest rot)")

    # ------------------------------------------------------------------ #
    def _path_and_limit(self) -> Tuple[str, int]:
        import os

        if hasattr(self.wal, "synced_size"):
            return self.wal.path, int(self.wal.synced_size)
        path = os.fspath(self.wal)
        try:
            return path, os.path.getsize(path)
        except OSError:
            return path, 0

    def scrub_once(self) -> List[AuditFinding]:
        """One full sweep of the sealed region; returns NEW findings."""
        from repro.serve.wal import (
            _DIG_MAGIC,
            _FILE_MAGIC,
            _REC_HDR,
            _REC_MAGIC,
        )

        path, limit = self._path_and_limit()
        try:
            with open(path, "rb") as f:
                data = f.read(limit)
        except OSError:
            return []
        new: List[AuditFinding] = []
        off = len(_FILE_MAGIC)
        if len(data) < off or data[:off] != _FILE_MAGIC:
            if data and off not in self._reported:
                self._reported.add(0)
                new.append(self._quarantine(None, 0, "bad file header"))
            return new
        while off + _REC_HDR.size <= len(data):
            magic, version, length, crc = _REC_HDR.unpack_from(data, off)
            if magic not in (_REC_MAGIC, _DIG_MAGIC):
                if off not in self._reported:
                    self._reported.add(off)
                    new.append(self._quarantine(
                        None, off, f"bad record magic {magic!r}"))
                break  # cannot trust the length field to skip past
            end = off + _REC_HDR.size + length
            if end > len(data):
                break  # straddles the sealed boundary: judged next sweep
            payload = data[off + _REC_HDR.size: end]
            self.records_verified += 1
            self._m_records.inc()
            if zlib.crc32(payload) & 0xFFFFFFFF != crc:
                if off not in self._reported:
                    self._reported.add(off)
                    new.append(self._quarantine(
                        int(version), off,
                        f"payload crc mismatch in sealed "
                        f"{'digest' if magic == _DIG_MAGIC else 'batch'} "
                        f"record ({length} bytes)"))
            off = end  # header intact: length is trustworthy, keep going
        self.sweeps += 1
        self._m_sweeps.inc()
        return new

    def _quarantine(self, version: Optional[int], offset: int,
                    detail: str) -> AuditFinding:
        f = AuditFinding(source="scrub", version=version, wal_offset=offset,
                         detail=detail)
        self.findings.append(f)
        self.corruptions += 1
        self._m_corrupt.inc()
        if self.flight is not None:
            self.flight.record("scrub", version=version, offset=offset,
                               detail=detail)
        return f

    # --------------------------- lifecycle ---------------------------- #
    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> "WalScrubber":
        if not self.running:
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._loop, name="wal-scrubber", daemon=True)
            self._thread.start()
        return self

    def stop(self, timeout: float = 10.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            self._thread = None

    def _loop(self) -> None:
        self.tracer.name_thread()
        while not self._stop.is_set():
            try:
                self.scrub_once()
            except Exception:
                pass  # a scrub bug must never take the service down
            self._stop.wait(self.interval_s)

    def __enter__(self) -> "WalScrubber":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------------ #
    @property
    def stats(self) -> Dict:
        return {
            "sweeps": self.sweeps,
            "records_verified": self.records_verified,
            "corruptions": self.corruptions,
            "interval_s": self.interval_s,
            "running": self.running,
            "findings": [f.to_dict() for f in self.findings],
        }
