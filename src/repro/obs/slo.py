"""SLO accounting: per-request-class latency scored against its deadline.

Each :class:`~repro.serve.window_service.RequestClass` carries
``max_delay_ms`` — the continuous-batching deadline the async tier
schedules against.  The SLO question is the measured converse: *of the
tickets actually served in class C, what fraction finished within C's
target, and what are the latency quantiles?*  ROADMAP direction 1's
"measure per-class p99 against ``max_delay_ms`` and adapt" starts here.

:class:`SLOTracker` owns three instrument families in the shared registry
(so the numbers appear in every snapshot/Prometheus export, not a side
channel):

* ``repro_request_latency_seconds{cls}`` — histogram, end-to-end ticket
  latency (submit to finish, the submitter-observed number);
* ``repro_requests_total{cls, outcome}`` — counter, outcomes ``ok`` /
  ``error`` / ``shed``;
* ``repro_slo_within_target_total{cls}`` — counter, ``ok`` tickets whose
  latency was <= the class target.

Attainment is exact (compared per ticket at observe time, not estimated
from buckets); quantiles are the histogram's interpolated estimates.
"""

from __future__ import annotations

import threading
from typing import Dict, Optional

__all__ = ["SLOTracker"]


class SLOTracker:
    """Score served tickets against their request class's latency target.

    ``registry`` may be a live :class:`~repro.obs.metrics.MetricsRegistry`
    or a :class:`~repro.obs.metrics.NullRegistry` (every observe becomes a
    no-op and :meth:`report` returns empty classes).
    """

    def __init__(self, registry):
        self.registry = registry
        self._lat = registry.histogram(
            "repro_request_latency_seconds",
            "end-to-end ticket latency (submit to finish)", labels=("cls",))
        self._req = registry.counter(
            "repro_requests_total", "finished tickets by outcome",
            labels=("cls", "outcome"))
        self._within = registry.counter(
            "repro_slo_within_target_total",
            "ok tickets within their class max_delay_ms", labels=("cls",))
        self._targets: Dict[str, Optional[float]] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------------ #
    def observe(self, cls: str, latency_s: float,
                target_s: Optional[float] = None,
                outcome: str = "ok") -> None:
        """Record one finished ticket.  ``target_s`` is the class's
        ``max_delay_ms / 1e3`` (None = no target: latency is recorded,
        attainment is not scored)."""
        if cls not in self._targets or (
                target_s is not None and self._targets.get(cls) is None):
            with self._lock:
                self._targets.setdefault(cls, None)
                if target_s is not None:
                    self._targets[cls] = float(target_s)
        self._req.labels(cls, outcome).inc()
        if outcome != "shed":
            self._lat.labels(cls).observe(latency_s)
        if outcome == "ok" and target_s is not None \
                and latency_s <= target_s:
            self._within.labels(cls).inc()

    # ------------------------------------------------------------------ #
    def counts(self, cls: str) -> Dict[str, float]:
        """Raw cumulative counters for ``cls`` (``ok`` / ``error`` /
        ``shed`` / ``within``) — the delta source for controllers that
        score *windowed* attainment between steps rather than the
        cumulative ratio (:class:`~repro.serve.window_service.
        SLOController`).  All zeros under a :class:`~repro.obs.metrics.
        NullRegistry`."""
        return {
            "ok": float(self._req.labels(cls, "ok").value),
            "error": float(self._req.labels(cls, "error").value),
            "shed": float(self._req.labels(cls, "shed").value),
            "within": float(self._within.labels(cls).value),
        }

    def report(self) -> Dict[str, Dict]:
        """Per-class scorecard: count/ok/error/shed, attainment in [0, 1]
        (ok-and-within-target over ok), and p50/p95/p99 in milliseconds."""
        out: Dict[str, Dict] = {}
        with self._lock:
            targets = dict(self._targets)
        for cls, target in sorted(targets.items()):
            ok = self._req.labels(cls, "ok").value
            err = self._req.labels(cls, "error").value
            shed = self._req.labels(cls, "shed").value
            lat = self._lat.labels(cls)
            out[cls] = {
                "target_ms": None if target is None else target * 1e3,
                "ok": int(ok),
                "error": int(err),
                "shed": int(shed),
                "attainment": (
                    None if target is None
                    else self._within.labels(cls).value / max(ok, 1.0)),
                "p50_ms": lat.quantile(0.50) * 1e3,
                "p95_ms": lat.quantile(0.95) * 1e3,
                "p99_ms": lat.quantile(0.99) * 1e3,
            }
        return out
