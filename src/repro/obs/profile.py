"""ANALYZE for compiled window plans: one profiled execution, per-phase.

``analyze_session(session)`` (surfaced as :meth:`Session.analyze`) runs
the session's compiled groups **once** under a phase-decomposed scope and
returns an :class:`AnalyzeReport` attributing wall time to named phases:

* device DBIndex terms decompose into ``pass1_gather`` →
  ``pass1_reduce`` → ``pass2_gather`` → ``pass2_reduce`` → ``finalize``
  (the same math as the fused jitted core, evaluated eagerly with a
  device sync after each phase so the timings are real, not dispatch
  shadows);
* device I-Index terms decompose into ``gather`` → ``wd_reduce`` →
  ``inherit`` → ``finalize``;
* host, stateless, and sharded terms run as one ``materialize`` phase
  (their internal phases live on the other side of a runner/shard_map
  boundary);
* algebraic programs add a ``host_combine`` phase;
* input staging (artifact lookup, dtype cast + device put) is charged to
  an explicit ``host_prep`` phase rather than hiding in the residue.

Because every phase blocks on its device results before the clock stops,
the sum of phase times accounts for (>= 95% of) the profiled wall time by
construction — the residue is Python glue between phases.  The eager
evaluation never touches the tracked jitted executors, so ANALYZE cannot
perturb the zero-recompile counters it is often run next to.  Spans are
also emitted on the session's tracer (one ``analyze.phase`` span per
phase) so a Chrome trace shows the same decomposition.

Cache-hit attribution (when a result cache is attached to the session)
and serving-bucket padding waste (via
:meth:`WindowService.debug_report`) complete the picture.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List, Optional

import numpy as np

__all__ = ["AnalyzeReport", "analyze_session"]


@dataclasses.dataclass
class AnalyzeReport:
    """One profiled run: phases, totals, and attribution quality."""

    wall_s: float
    phases: List[Dict]  # [{group, term, phase, seconds}]
    attributed_s: float
    attribution: float  # attributed_s / wall_s
    phase_totals: Dict  # phase name -> seconds summed across terms
    cache: Dict  # result-cache attribution (empty if none attached)
    version: int

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True, **kw)

    def text(self) -> str:
        L = [f"ANALYZE: wall={self.wall_s * 1e3:.3f} ms, "
             f"attributed={self.attributed_s * 1e3:.3f} ms "
             f"({self.attribution * 100:.1f}%), version={self.version}"]
        width = max((len(p) for p in self.phase_totals), default=10)
        for name, sec in sorted(self.phase_totals.items(),
                                key=lambda kv: -kv[1]):
            share = sec / self.wall_s if self.wall_s else 0.0
            L.append(f"  {name:<{width}}  {sec * 1e3:9.3f} ms  "
                     f"{share * 100:5.1f}%")
        for p in self.phases:
            L.append(f"    group {p['group']} term {p['term']} "
                     f"{p['phase']}: {p['seconds'] * 1e3:.3f} ms")
        if self.cache:
            L.append(f"  cache: {self.cache}")
        return "\n".join(L)


class _PhaseClock:
    """Collects (group, term, phase) -> seconds; blocks device results
    inside the timed region so a phase owns its own compute."""

    def __init__(self, tracer):
        self.rows: List[Dict] = []
        self._tracer = tracer

    def timed(self, group: int, term: str, phase: str, fn):
        import jax

        with self._tracer.span("analyze.phase", cat="analyze",
                               phase=phase, term=term):
            t0 = time.perf_counter()
            out = fn()
            out = jax.block_until_ready(out)
            dt = time.perf_counter() - t0
        self.rows.append({"group": group, "term": term, "phase": phase,
                          "seconds": dt})
        return out


# ---------------------------------------------------------------------- #
#  Phase-decomposed eager executions (mirror the fused jitted cores)
# ---------------------------------------------------------------------- #
def _analyze_dbindex_term(clock: _PhaseClock, gi: int, tname: str, plan,
                          values, aggs, opts) -> Dict:
    import jax.numpy as jnp

    from repro.core.aggregates import pack_channels
    from repro.core.engine_jax import _minmax_pass1, _minmax_pass2
    from repro.kernels.segment_reduce.ops import segment_sum_gathered

    use_pallas = opts.get("use_pallas", True)
    interpret = opts.get("interpret")
    pack = pack_channels(tuple(aggs))
    # device put + dtype cast is real work — charge it to its own phase
    values = clock.timed(gi, tname, "host_prep",
                         lambda: jnp.asarray(values, jnp.float32))
    sum_cols = pack.channels_of("sum")
    minmax_cols = [(ci, m, s) for ci, (m, s) in enumerate(pack.channels)
                   if m != "sum"]

    need_g1 = any(pack.channels[ci][1] in ("value", "square")
                  for ci in sum_cols) or (plan.p1_ell is None and minmax_cols)
    g1 = None
    if need_g1:
        g1 = clock.timed(gi, tname, "pass1_gather",
                         lambda: jnp.take(values, plan.pass1.gather_padded))

    def _pass1():
        t_cols = {}
        for ci in sum_cols:
            src = pack.channels[ci][1]
            if src == "ones":
                t_cols[ci] = plan.block_sizes
            else:
                t_cols[ci] = segment_sum_gathered(
                    plan.pass1, g1 if src == "value" else g1 * g1,
                    use_pallas=use_pallas, interpret=interpret)
        for ci, mname, src in minmax_cols:
            vsrc = values if src == "value" else values * values
            gsrc = g1 if (g1 is None or src == "value") else g1 * g1
            t_cols[ci] = _minmax_pass1(plan, vsrc, mname, gathered=gsrc)
        return t_cols

    t_cols = clock.timed(gi, tname, "pass1_reduce", _pass1)

    outs = {}
    if sum_cols:
        g2 = clock.timed(
            gi, tname, "pass2_gather",
            lambda: jnp.take(
                jnp.stack([t_cols[ci] for ci in sum_cols], axis=1),
                plan.pass2.gather_padded, axis=0))

        def _pass2():
            reduced = segment_sum_gathered(
                plan.pass2, g2, use_pallas=use_pallas, interpret=interpret)
            if reduced.ndim == 1:
                reduced = reduced[:, None]
            return {ci: reduced[:, j] for j, ci in enumerate(sum_cols)}

        outs.update(clock.timed(gi, tname, "pass2_reduce", _pass2))
    if minmax_cols:
        def _pass2_minmax():
            return {ci: _minmax_pass2(plan, t_cols[ci], mname)
                    for ci, mname, _ in minmax_cols}

        outs.update(clock.timed(gi, tname, "pass2_reduce", _pass2_minmax))

    chans = tuple(outs[ci] for ci in range(len(pack.channels)))
    return clock.timed(
        gi, tname, "finalize",
        lambda: {a: np.asarray(pack.finalize(i, chans, xp=jnp))
                 for i, a in enumerate(aggs)})


def _analyze_iindex_term(clock: _PhaseClock, gi: int, tname: str, plan,
                         values, aggs, opts) -> Dict:
    import jax.numpy as jnp

    from repro.core.aggregates import pack_channels
    from repro.core.engine_jax import (
        _inherit_scan,
        _segment_minmax_gathered,
    )
    from repro.kernels.segment_reduce.ops import segment_sum_gathered

    use_pallas = opts.get("use_pallas", True)
    interpret = opts.get("interpret")
    schedule = opts.get("schedule", "level")
    pack = pack_channels(tuple(aggs))
    values = clock.timed(gi, tname, "host_prep",
                         lambda: jnp.asarray(values, jnp.float32))
    n = plan.n

    def _gather():
        ones = jnp.ones(n, jnp.float32)
        srcs = {"value": values, "ones": ones, "square": values * values}
        cols = jnp.stack([srcs[src] for _, src in pack.channels], axis=1)
        return jnp.take(cols, plan.wd_plan.gather_padded, axis=0)

    g = clock.timed(gi, tname, "gather", _gather)
    chans = [None] * len(pack.channels)
    sum_cols = pack.channels_of("sum")

    def _wd_reduce():
        parts = {}
        if sum_cols:
            wdp = segment_sum_gathered(plan.wd_plan, g[:, list(sum_cols)],
                                       use_pallas=use_pallas,
                                       interpret=interpret)
            parts["sum"] = wdp[:, None] if wdp.ndim == 1 else wdp
        for mname in ("min", "max"):
            for ci in pack.channels_of(mname):
                # string key: pytree dict flatten sorts keys, so mixing
                # str and tuple keys would break block_until_ready
                parts[f"{mname}:{ci}"] = _segment_minmax_gathered(
                    plan.wd_plan, g[:, ci], n, mname)
        return parts

    parts = clock.timed(gi, tname, "wd_reduce", _wd_reduce)

    def _inherit():
        if sum_cols:
            done = _inherit_scan(parts["sum"], plan.pid, plan.level,
                                 plan.max_level, n, "sum", schedule)
            for j, ci in enumerate(sum_cols):
                chans[ci] = done[:, j]
        for mname in ("min", "max"):
            for ci in pack.channels_of(mname):
                chans[ci] = _inherit_scan(parts[f"{mname}:{ci}"], plan.pid,
                                          plan.level, plan.max_level, n,
                                          mname, schedule)
        return [c for c in chans if c is not None]

    clock.timed(gi, tname, "inherit", _inherit)
    return clock.timed(
        gi, tname, "finalize",
        lambda: {a: np.asarray(pack.finalize(i, tuple(chans), xp=jnp))
                 for i, a in enumerate(aggs)})


# ---------------------------------------------------------------------- #
def analyze_session(session, spec=None, values=None) -> AnalyzeReport:
    """Execute the selected groups once, phase-profiled (see module doc).

    ``spec`` filters like :func:`~repro.obs.explain.explain_session`;
    ``values`` overrides the graph attribute(s) as in ``Session.run``.
    """
    from repro.obs.explain import _match_groups

    clock = _PhaseClock(session.tracer)
    cache_before = _cache_stats(session)
    t_start = time.perf_counter()
    for gi in _match_groups(session, spec):
        grp = session.compiled.groups[gi]
        prog = session._programs[gi]

        def _prep(gi=gi, grp=grp):
            return (session._group_artifacts(gi),
                    session._values_for(grp, values))

        arts, vals = clock.timed(gi, "-", "host_prep", _prep)
        aggs = prog.term_aggs if prog is not None else grp.aggs
        term_outs = []
        for term, (index, plan) in zip(session._group_terms(gi), arts):
            tname = term.name()
            cls = type(plan).__name__ if plan is not None else None
            if cls == "DBIndexPlan":
                out = _analyze_dbindex_term(clock, gi, tname, plan, vals,
                                            aggs, session._opts)
            elif cls == "IIndexPlan":
                out = _analyze_iindex_term(clock, gi, tname, plan, vals,
                                           aggs, session._opts)
            else:
                # host / stateless / sharded: the runner is the phase —
                # its internals live behind a runner or shard_map boundary
                out = clock.timed(
                    gi, tname, "materialize",
                    lambda term=term, index=index, plan=plan:
                        session._exec_term(grp, term, index, plan, vals,
                                           session.graph, aggs))
            term_outs.append(out)
        if prog is not None:
            from repro.core.api import _combine_program

            clock.timed(gi, "-", "host_combine",
                        lambda: _combine_program(prog, grp.aggs, term_outs))
    wall = time.perf_counter() - t_start

    attributed = sum(p["seconds"] for p in clock.rows)
    totals: Dict[str, float] = {}
    for p in clock.rows:
        totals[p["phase"]] = totals.get(p["phase"], 0.0) + p["seconds"]
    return AnalyzeReport(
        wall_s=wall,
        phases=clock.rows,
        attributed_s=attributed,
        attribution=(attributed / wall) if wall > 0 else 1.0,
        phase_totals=totals,
        cache=_cache_delta(cache_before, _cache_stats(session)),
        version=int(session.version),
    )


def _cache_stats(session) -> Dict:
    cache = getattr(session, "_result_cache", None)
    if cache is None:
        return {}
    out = {}
    for k in ("hits", "misses", "invalidations", "evictions"):
        v = getattr(cache, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _cache_delta(before: Dict, after: Dict) -> Dict:
    if not after:
        return {}
    out = {k: after[k] for k in after}
    hits = after.get("hits", 0)
    misses = after.get("misses", 0)
    out["hit_rate"] = hits / max(hits + misses, 1)
    out["during_run"] = {k: after[k] - before.get(k, 0) for k in after}
    return out
