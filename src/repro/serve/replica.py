"""Read replicas: follower sessions tailing the leader's write-ahead log.

The WAL (:mod:`repro.serve.wal`) is append-before-apply, so its durable
prefix is exactly the leader's update history.  A replica is a follower
:class:`~repro.core.api.Session` built from the same base graph + specs
that *tails the log file by byte offset*: :meth:`ReadReplica.poll` decodes
any newly appended records (:func:`repro.serve.wal.read_wal_records`
returns the next offset, tolerating a partially appended tail) and applies
them through the ordinary incremental maintenance path — the follower pays
the same patch costs as the leader and stays recompile-free.

Serving is MVCC like the leader's: applied batches advance the follower's
write head, but readers stay **pinned** at the replica's published
snapshot until :meth:`ReadReplica.flip` — a lagging replica keeps serving
a consistent old version (never a half-applied one), and
:meth:`catch_up` = poll + flip.  Results at any published version are
bit-identical to what the leader served at that version: both sides ran
the same batches through the same deterministic maintenance.

Self-checking: the leader stamps a per-version content digest into the
WAL (:meth:`repro.serve.wal.WriteAheadLog.append_digest`); when
``verify_digests`` is on (the default) the replica recomputes its own
digest whenever a poll lands on the leader's digest for its current head
version and compares (:func:`repro.obs.audit.digests_match`).  The first
disagreement is quarantined as an :class:`~repro.obs.audit.AuditFinding`
on :attr:`ReadReplica.divergence`, attributed to the first bad version
*and* the digest record's WAL byte offset — the health monitor treats it
as a hard failure.  ``check_plan_digest=False`` skips the plan component
for replicas deliberately running a different engine configuration (graph
and result digests must still agree: the bit-identity invariant).

For sharded runtimes the update stream can also be propagated *below* the
session, as the changed-tile-group patch messages of
:func:`repro.distributed.window_runtime.patch_sharded_plan` (its ``wire``
output) applied with :func:`repro.distributed.window_runtime.
apply_wire_message` — shipping only the dirty tiles instead of re-deriving
them (wire messages carry their own ``plan_crc`` stamp).  The WAL path
above remains the source of truth; the wire path is the transport
optimization for followers that already hold a plan shard.
"""

from __future__ import annotations

import json
import os
import threading
from typing import Dict, Optional

from repro import obs as _obs
from repro.core.api import Session
from repro.serve.wal import scan_wal_entries
from repro.serve.window_service import WindowService


class ReadReplica:
    """A follower :class:`Session` + serving front end fed from a WAL file.

    ``graph`` and ``specs`` must match what the leader's session was built
    from (the log holds only the *updates*); ``session_kw`` forwards to the
    follower's Session constructor, so a replica may run a different
    engine/device configuration than the leader — results are still
    bit-identical because every engine agrees with the set-evaluation
    semantics.
    """

    def __init__(self, graph, specs, wal_path, *, bucket: int = 8,
                 use_cache: bool = True, obs=None,
                 verify_digests: bool = True,
                 verify_results: bool = False,
                 check_plan_digest: bool = True, **session_kw):
        self.path = os.fspath(wal_path)
        self.obs = obs if obs is not None else _obs.get_registry()
        self.session = Session(graph, specs, **session_kw)
        #: serving front end pinned behind the apply head (auto_flip off:
        #: publishing is the replica's explicit flip decision)
        self.service = WindowService(self.session, bucket=bucket,
                                     auto_flip=False, use_cache=use_cache,
                                     obs=self.obs)
        self._offset = 0  # byte offset of the next unread WAL record
        self.records_applied = 0
        self.polls = 0
        #: compare leader digest records against a locally recomputed one
        self.verify_digests = bool(verify_digests)
        #: fold served result vectors into the local digest (end-to-end
        #: served-bytes check; costs one fused launch per group per digest)
        self.verify_results = bool(verify_results)
        #: compare the plan component too — disable when this replica runs
        #: a different engine configuration than the leader
        self.check_plan_digest = bool(check_plan_digest)
        #: first divergence finding (None while leader and follower agree)
        self.divergence = None
        self.digest_checks = 0
        self._tail_thread: Optional[threading.Thread] = None
        self._tail_stop = threading.Event()
        self._m_polls = self.obs.counter(
            "repro_replica_polls_total", "WAL tail polls")
        self._m_records = self.obs.counter(
            "repro_replica_records_total", "WAL records applied")
        self._m_digest_checks = self.obs.counter(
            "repro_replica_digest_checks_total",
            "leader digests verified against local recomputation")
        self._m_divergence = self.obs.counter(
            "repro_replica_divergence_total",
            "leader/follower digest disagreements (quarantined)")
        self._g_lag_bytes = self.obs.gauge(
            "repro_replica_lag_bytes", "unapplied WAL bytes at last check")
        self._g_lag_versions = self.obs.gauge(
            "repro_replica_lag_versions",
            "applied-but-unpublished versions at last check")

    # ------------------------------------------------------------------ #
    def poll(self, upto_version: Optional[int] = None) -> int:
        """Apply newly appended WAL records to the follower's write head
        (readers stay pinned).  Returns the number applied.

        ``upto_version`` stops early — a replica can deliberately hold at
        a point-in-time version.  Unconsumed records stay unconsumed (the
        offset only advances past applied records), so a later poll
        resumes exactly there.

        Digest records encountered along the way are verified against a
        locally recomputed digest when they land on the current head
        version (see ``verify_digests``); the first disagreement is
        quarantined on :attr:`divergence`.
        """
        entries, end = scan_wal_entries(self.path, self._offset)
        self.polls += 1
        self._m_polls.inc()
        applied = 0
        offset = end if entries else max(self._offset, end)
        for e in entries:
            if upto_version is not None and e["version"] > upto_version:
                # partial consumption: resume exactly at this record
                offset = e["offset"]
                break
            if e["kind"] == "batch":
                self.session.update(e["batch"])
                applied += 1
            elif self.verify_digests \
                    and e["version"] == self.session.version:
                self._check_digest(e)
        self._offset = max(self._offset, offset)
        self.records_applied += applied
        self._m_records.inc(applied)
        return applied

    def _check_digest(self, entry: Dict) -> None:
        """Compare the leader's digest record against a fresh local one."""
        from repro.obs.audit import AuditFinding, digests_match

        leader = entry["digest"]
        local = self.session.digest(
            include_results=self.verify_results
            and "result_crc" in leader)
        self.digest_checks += 1
        self._m_digest_checks.inc()
        ok, detail = digests_match(leader, local,
                                   check_plans=self.check_plan_digest)
        if ok or self.divergence is not None:
            return
        self.divergence = AuditFinding(
            source="digest", version=int(entry["version"]),
            expected=json.dumps(leader, sort_keys=True).encode(),
            got=json.dumps(local, sort_keys=True).encode(),
            wal_offset=int(entry["offset"]), detail=detail)
        self._m_divergence.inc()
        self.service.flight.record(
            "divergence", version=int(entry["version"]),
            wal_offset=int(entry["offset"]), detail=detail)

    def flip(self) -> int:
        """Publish the apply head to readers (one snapshot swap)."""
        return self.service.flip()

    def catch_up(self) -> int:
        """Poll to the end of the log, then publish.  Returns the number
        of records applied."""
        n = self.poll()
        self.flip()
        return n

    # --------------------------- background tail ----------------------- #
    @property
    def tailing(self) -> bool:
        return self._tail_thread is not None and self._tail_thread.is_alive()

    def start_tailing(self, interval_s: float = 0.05) -> "ReadReplica":
        """Catch up continuously on a background thread (``replica-tail``)
        until :meth:`stop_tailing`."""
        if not self.tailing:
            self._tail_stop.clear()
            self._tail_thread = threading.Thread(
                target=self._tail_loop, args=(float(interval_s),),
                name="replica-tail", daemon=True)
            self._tail_thread.start()
        return self

    def stop_tailing(self, timeout: float = 10.0) -> None:
        self._tail_stop.set()
        if self._tail_thread is not None:
            self._tail_thread.join(timeout=timeout)
            self._tail_thread = None

    def _tail_loop(self, interval_s: float) -> None:
        self.service.tracer.name_thread()
        while not self._tail_stop.is_set():
            try:
                self.catch_up()
            except Exception:
                pass  # a tail hiccup must not kill the thread; retry
            self._tail_stop.wait(interval_s)

    # ------------------------------------------------------------------ #
    @property
    def version(self) -> int:
        """The published (reader-visible) version."""
        return self.service.version

    @property
    def head_version(self) -> int:
        """The applied-but-possibly-unpublished version."""
        return self.session.version

    @property
    def lag(self) -> Dict:
        """How far behind the log this replica is: unapplied bytes in the
        file plus unpublished versions at the head."""
        try:
            size = os.path.getsize(self.path)
        except OSError:
            size = 0
        behind = max(size - self._offset, 0)
        unpublished = self.session.version - self.service.version
        self._g_lag_bytes.set(behind)
        self._g_lag_versions.set(unpublished)
        return {
            "behind_bytes": behind,
            "unpublished_versions": unpublished,
            "published_version": self.service.version,
            "head_version": self.session.version,
        }

    # ------------------------------- reads ---------------------------- #
    def query(self, spec, vertex: Optional[int] = None, values=None):
        """Serve one read at the published version."""
        return self.service.query(spec, vertex=vertex, values=values)

    @property
    def stats(self) -> Dict:
        out = dict(self.service.stats)
        out.update(records_applied=self.records_applied, polls=self.polls,
                   digest_checks=self.digest_checks,
                   diverged=self.divergence is not None,
                   tailing=self.tailing, lag=self.lag)
        if self.divergence is not None:
            out["divergence"] = self.divergence.to_dict()
        return out
