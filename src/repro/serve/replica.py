"""Read replicas: follower sessions tailing the leader's write-ahead log.

The WAL (:mod:`repro.serve.wal`) is append-before-apply, so its durable
prefix is exactly the leader's update history.  A replica is a follower
:class:`~repro.core.api.Session` built from the same base graph + specs
that *tails the log* — a single file by byte offset, or a rotated
segment directory by ``(segment, offset)`` cursor
(:func:`repro.serve.wal.scan_segmented_entries`): :meth:`ReadReplica.poll`
decodes any newly appended records (a partially appended tail is simply
retried; sealed segments are consumed whole and never skipped) and
applies them through the ordinary incremental maintenance path — the
follower pays the same patch costs as the leader and stays
recompile-free.

Serving is MVCC like the leader's: applied batches advance the follower's
write head, but readers stay **pinned** at the replica's published
snapshot until :meth:`ReadReplica.flip` — a lagging replica keeps serving
a consistent old version (never a half-applied one), and
:meth:`catch_up` = poll + flip.  Results at any published version are
bit-identical to what the leader served at that version: both sides ran
the same batches through the same deterministic maintenance.

Rejoin after a kill is **checkpoint + tail**
(:meth:`ReadReplica.from_checkpoint`): the follower session is rebuilt
from the newest snapshot checkpoint (:mod:`repro.serve.checkpoint`), its
cursor is sought past the checkpoint version
(:func:`repro.serve.wal.seek_segmented`), and only the bounded tail is
replayed.  A cursor pointing below the oldest retained segment raises
:class:`~repro.serve.wal.WalTruncatedError` — the signal that a stale
follower must rejoin through a checkpoint rather than its old offset.

Self-checking: the leader stamps a per-version content digest into the
WAL (:meth:`repro.serve.wal.WriteAheadLog.append_digest`); when
``verify_digests`` is on (the default) the replica recomputes its own
digest whenever a poll lands on the leader's digest for its current head
version and compares (:func:`repro.obs.audit.digests_match`).  The first
disagreement is quarantined as an :class:`~repro.obs.audit.AuditFinding`
on :attr:`ReadReplica.divergence`, attributed to the first bad version
*and* the digest record's WAL byte offset — the health monitor treats it
as a hard failure.  ``check_plan_digest=False`` skips the plan component
for replicas deliberately running a different engine configuration *and*
for checkpoint-restored followers (a freshly built plan legitimately
differs byte-wise from the leader's incrementally patched one; graph and
result digests must still agree: the bit-identity invariant).

Replica metrics are **per-replica labeled** (``{replica="<name>"}`` on
every gauge/counter, Prometheus-exported) and resolve the registry at
call time, so a replica constructed before ``obs.enable()`` still lands
its lag gauges in the live registry afterwards — the same
late-binding rule as the PR-9 collector fix.

For sharded runtimes the update stream can also be propagated *below* the
session, as the changed-tile-group patch messages of
:func:`repro.distributed.window_runtime.patch_sharded_plan` (its ``wire``
output) applied with :func:`repro.distributed.window_runtime.
apply_wire_message` — shipping only the dirty tiles instead of re-deriving
them (wire messages carry their own ``plan_crc`` stamp).  The WAL path
above remains the source of truth; the wire path is the transport
optimization for followers that already hold a plan shard.
"""

from __future__ import annotations

import json
import os
import threading
from typing import Dict, Optional, Tuple

from repro import obs as _obs
from repro.core.api import Session
from repro.serve.wal import (
    WalTruncatedError,
    list_segments,
    scan_segmented_entries,
    scan_wal_entries,
    seek_segmented,
)
from repro.serve.window_service import WindowService


class ReadReplica:
    """A follower :class:`Session` + serving front end fed from a WAL.

    ``graph`` and ``specs`` must match what the leader's session was built
    from (the log holds only the *updates*); ``session_kw`` forwards to the
    follower's Session constructor, so a replica may run a different
    engine/device configuration than the leader — results are still
    bit-identical because every engine agrees with the set-evaluation
    semantics.

    ``wal_path`` is a single log file *or* a segment directory (also
    accepts a live ``WriteAheadLog`` / ``SegmentedWriteAheadLog`` — the
    replica tails its files).  ``name`` labels this replica's metrics;
    ``start_version`` resumes version numbering from a checkpoint restore
    (use :meth:`from_checkpoint` rather than passing it directly).
    """

    def __init__(self, graph, specs, wal_path, *, bucket: int = 8,
                 use_cache: bool = True, obs=None,
                 name: str = "replica",
                 verify_digests: bool = True,
                 verify_results: bool = False,
                 check_plan_digest: bool = True,
                 start_version: int = 0, **session_kw):
        if hasattr(wal_path, "directory"):
            wal_path = wal_path.directory
        elif hasattr(wal_path, "path"):
            wal_path = wal_path.path
        self.path = os.fspath(wal_path)
        self.name = str(name)
        self._obs_explicit = obs
        self._segmented = os.path.isdir(self.path)
        self.session = Session(graph, specs, **session_kw)
        if start_version:
            self.session.version = int(start_version)
        #: serving front end pinned behind the apply head (auto_flip off:
        #: publishing is the replica's explicit flip decision)
        self.service = WindowService(self.session, bucket=bucket,
                                     auto_flip=False, use_cache=use_cache,
                                     obs=self.obs)
        self._offset = 0  # single-file mode: next unread byte
        #: segmented mode: (segment base version, byte offset) of the next
        #: unread record
        self._cursor: Tuple[int, int] = (0, 0)
        if self._segmented and start_version:
            self._cursor = seek_segmented(self.path, int(start_version))
        #: version this replica was restored from (0 = built from base)
        self.restored_from_version = int(start_version)
        #: False once :meth:`kill` ran — routers/health exclude the replica
        self.alive = True
        self.records_applied = 0
        self.polls = 0
        #: compare leader digest records against a locally recomputed one
        self.verify_digests = bool(verify_digests)
        #: fold served result vectors into the local digest (end-to-end
        #: served-bytes check; costs one fused launch per group per digest)
        self.verify_results = bool(verify_results)
        #: compare the plan component too — disable when this replica runs
        #: a different engine configuration than the leader
        self.check_plan_digest = bool(check_plan_digest)
        #: first divergence finding (None while leader and follower agree)
        self.divergence = None
        self.digest_checks = 0
        self._tail_thread: Optional[threading.Thread] = None
        self._tail_stop = threading.Event()

    # --------------------------- metrics ------------------------------- #
    @property
    def obs(self):
        """Registry resolved at *call* time (explicit one wins): metrics
        from a replica constructed before ``obs.enable()`` still reach the
        live registry."""
        return (self._obs_explicit if self._obs_explicit is not None
                else _obs.get_registry())

    def _metric(self, kind: str, metric_name: str, help_text: str):
        fam = getattr(self.obs, kind)(metric_name, help_text,
                                      labels=("replica",))
        return fam.labels(self.name)

    # ------------------------------------------------------------------ #
    @property
    def cursor(self) -> Dict:
        """The tailing cursor: ``{"segment": base_version_or_None,
        "offset": byte_offset}``."""
        if self._segmented:
            return {"segment": self._cursor[0], "offset": self._cursor[1]}
        return {"segment": None, "offset": self._offset}

    def _scan(self):
        """New entries past the cursor plus the advanced cursor."""
        if self._segmented:
            try:
                return scan_segmented_entries(self.path, self._cursor)
            except WalTruncatedError:
                # The cursor's segment was truncated away.  That is legal
                # only when this replica had fully consumed it (truncation
                # waits for the slowest *live* cursor's applied version) —
                # re-seek from our own head; a replica genuinely behind
                # the truncation point re-raises here and must rejoin
                # from a checkpoint.
                self._cursor = seek_segmented(
                    self.path, self.session.version)
                return scan_segmented_entries(self.path, self._cursor)
        entries, end = scan_wal_entries(self.path, self._offset)
        return entries, (None, end if entries else max(self._offset, end))

    def poll(self, upto_version: Optional[int] = None) -> int:
        """Apply newly appended WAL records to the follower's write head
        (readers stay pinned).  Returns the number applied.

        ``upto_version`` stops early — a replica can deliberately hold at
        a point-in-time version.  Unconsumed records stay unconsumed (the
        cursor only advances past applied records), so a later poll
        resumes exactly there.

        Digest records encountered along the way are verified against a
        locally recomputed digest when they land on the current head
        version (see ``verify_digests``); the first disagreement is
        quarantined on :attr:`divergence`.  A gap in the version sequence
        (history truncated below the cursor) raises
        :class:`~repro.serve.wal.WalTruncatedError` — rejoin via
        :meth:`from_checkpoint`.
        """
        entries, cursor = self._scan()
        self.polls += 1
        self._metric("counter", "repro_replica_polls_total",
                     "WAL tail polls").inc()
        applied = 0
        stopped = None
        for e in entries:
            if upto_version is not None and e["version"] > upto_version:
                # partial consumption: resume exactly at this record
                stopped = e
                break
            if e["kind"] == "batch":
                if e["version"] > self.session.version + 1:
                    raise WalTruncatedError(
                        f"replica {self.name!r} at version "
                        f"{self.session.version} but next retained record "
                        f"is version {e['version']} — history truncated; "
                        f"rejoin from a checkpoint")
                if e["version"] <= self.session.version:
                    continue  # already folded in (checkpoint restore)
                self.session.update(e["batch"])
                applied += 1
            elif self.verify_digests \
                    and e["version"] == self.session.version:
                self._check_digest(e)
        if stopped is not None:
            cursor = (stopped.get("segment"), stopped["offset"])
        if self._segmented:
            self._cursor = (int(cursor[0]), int(cursor[1]))
        else:
            self._offset = max(self._offset, int(cursor[1]))
        self.records_applied += applied
        self._metric("counter", "repro_replica_records_total",
                     "WAL records applied").inc(applied)
        return applied

    def _check_digest(self, entry: Dict) -> None:
        """Compare the leader's digest record against a fresh local one."""
        from repro.obs.audit import AuditFinding, digests_match

        leader = entry["digest"]
        local = self.session.digest(
            include_results=self.verify_results
            and "result_crc" in leader)
        self.digest_checks += 1
        self._metric(
            "counter", "repro_replica_digest_checks_total",
            "leader digests verified against local recomputation").inc()
        ok, detail = digests_match(leader, local,
                                   check_plans=self.check_plan_digest)
        if ok or self.divergence is not None:
            return
        self.divergence = AuditFinding(
            source="digest", version=int(entry["version"]),
            expected=json.dumps(leader, sort_keys=True).encode(),
            got=json.dumps(local, sort_keys=True).encode(),
            wal_offset=int(entry["offset"]), detail=detail)
        self._metric(
            "counter", "repro_replica_divergence_total",
            "leader/follower digest disagreements (quarantined)").inc()
        self.service.flight.record(
            "divergence", version=int(entry["version"]),
            wal_offset=int(entry["offset"]), detail=detail)

    def flip(self) -> int:
        """Publish the apply head to readers (one snapshot swap)."""
        return self.service.flip()

    def catch_up(self) -> int:
        """Poll to the end of the log, then publish.  Returns the number
        of records applied."""
        n = self.poll()
        self.flip()
        return n

    # --------------------------- background tail ----------------------- #
    @property
    def tailing(self) -> bool:
        return self._tail_thread is not None and self._tail_thread.is_alive()

    def start_tailing(self, interval_s: float = 0.05) -> "ReadReplica":
        """Catch up continuously on a background thread (``replica-tail``)
        until :meth:`stop_tailing`."""
        if not self.tailing:
            self._tail_stop.clear()
            self._tail_thread = threading.Thread(
                target=self._tail_loop, args=(float(interval_s),),
                name=f"replica-tail-{self.name}", daemon=True)
            self._tail_thread.start()
        return self

    def stop_tailing(self, timeout: float = 10.0) -> None:
        self._tail_stop.set()
        if self._tail_thread is not None:
            self._tail_thread.join(timeout=timeout)
            self._tail_thread = None

    def kill(self) -> None:
        """Take this replica out of service (fault injection / retire):
        stops the tail daemon and marks it dead for routers and health."""
        self.alive = False
        self.stop_tailing()

    def _tail_loop(self, interval_s: float) -> None:
        self.service.tracer.name_thread()
        while not self._tail_stop.is_set():
            try:
                self.catch_up()
            except Exception:
                pass  # a tail hiccup must not kill the thread; retry
            self._tail_stop.wait(interval_s)

    # ------------------------------------------------------------------ #
    @property
    def version(self) -> int:
        """The published (reader-visible) version."""
        return self.service.version

    @property
    def head_version(self) -> int:
        """The applied-but-possibly-unpublished version."""
        return self.session.version

    def _behind_bytes(self) -> int:
        """Unconsumed log bytes past the cursor (lag heuristic)."""
        try:
            if not self._segmented:
                return max(os.path.getsize(self.path) - self._offset, 0)
            base, off = self._cursor
            behind = 0
            for b, p in list_segments(self.path):
                size = os.path.getsize(p)
                if b == base:
                    behind += max(size - off, 0)
                elif base == 0 or b > base:
                    behind += size
            return behind
        except OSError:
            return 0

    @property
    def lag(self) -> Dict:
        """How far behind the log this replica is: unapplied bytes in the
        retained segments plus unpublished versions at the head."""
        behind = self._behind_bytes()
        unpublished = self.session.version - self.service.version
        self._metric("gauge", "repro_replica_lag_bytes",
                     "unapplied WAL bytes at last check").set(behind)
        self._metric("gauge", "repro_replica_lag_versions",
                     "applied-but-unpublished versions at last check"
                     ).set(unpublished)
        return {
            "behind_bytes": behind,
            "unpublished_versions": unpublished,
            "published_version": self.service.version,
            "head_version": self.session.version,
        }

    # ------------------------------------------------------------------ #
    @classmethod
    def from_checkpoint(cls, specs, wal_path, checkpoint, *,
                        name: str = "replica", **kw) -> "ReadReplica":
        """Rejoin path: build a replica from the newest checkpoint, cursor
        sought past it, ready to tail only the bounded WAL tail.

        ``checkpoint`` is a checkpoint directory (newest file wins) or a
        single checkpoint file.  The restored follower runs with
        ``check_plan_digest=False`` unless overridden (fresh plan bytes
        legitimately differ from the leader's patched ones); result and
        graph digests still verify.  Raises
        :class:`~repro.serve.wal.WalTruncatedError` via the first
        :meth:`poll` if the tail past the checkpoint was truncated.
        """
        from repro.serve.checkpoint import latest_checkpoint, load_checkpoint

        ckpt = os.fspath(checkpoint)
        if os.path.isdir(ckpt):
            found = latest_checkpoint(ckpt)
            if found is None:
                raise FileNotFoundError(
                    f"no checkpoint under {ckpt!r} to rejoin from")
            ckpt = found[1]
        version, graph, _digest = load_checkpoint(ckpt)
        kw.setdefault("check_plan_digest", False)
        return cls(graph, specs, wal_path, name=name,
                   start_version=version, **kw)

    # ------------------------------- reads ---------------------------- #
    def query(self, spec, vertex: Optional[int] = None, values=None):
        """Serve one read at the published version."""
        return self.service.query(spec, vertex=vertex, values=values)

    @property
    def stats(self) -> Dict:
        out = dict(self.service.stats)
        out.update(name=self.name, alive=self.alive,
                   records_applied=self.records_applied, polls=self.polls,
                   digest_checks=self.digest_checks,
                   diverged=self.divergence is not None,
                   tailing=self.tailing, lag=self.lag, cursor=self.cursor,
                   restored_from_version=self.restored_from_version)
        if self.divergence is not None:
            out["divergence"] = self.divergence.to_dict()
        return out
