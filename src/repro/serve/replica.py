"""Read replicas: follower sessions tailing the leader's write-ahead log.

The WAL (:mod:`repro.serve.wal`) is append-before-apply, so its durable
prefix is exactly the leader's update history.  A replica is a follower
:class:`~repro.core.api.Session` built from the same base graph + specs
that *tails the log file by byte offset*: :meth:`ReadReplica.poll` decodes
any newly appended records (:func:`repro.serve.wal.read_wal_records`
returns the next offset, tolerating a partially appended tail) and applies
them through the ordinary incremental maintenance path — the follower pays
the same patch costs as the leader and stays recompile-free.

Serving is MVCC like the leader's: applied batches advance the follower's
write head, but readers stay **pinned** at the replica's published
snapshot until :meth:`ReadReplica.flip` — a lagging replica keeps serving
a consistent old version (never a half-applied one), and
:meth:`catch_up` = poll + flip.  Results at any published version are
bit-identical to what the leader served at that version: both sides ran
the same batches through the same deterministic maintenance.

For sharded runtimes the update stream can also be propagated *below* the
session, as the changed-tile-group patch messages of
:func:`repro.distributed.window_runtime.patch_sharded_plan` (its ``wire``
output) applied with :func:`repro.distributed.window_runtime.
apply_wire_message` — shipping only the dirty tiles instead of re-deriving
them.  The WAL path above remains the source of truth; the wire path is
the transport optimization for followers that already hold a plan shard.
"""

from __future__ import annotations

import os
from typing import Dict, Optional

from repro import obs as _obs
from repro.core.api import Session
from repro.serve.wal import read_wal_records
from repro.serve.window_service import WindowService


class ReadReplica:
    """A follower :class:`Session` + serving front end fed from a WAL file.

    ``graph`` and ``specs`` must match what the leader's session was built
    from (the log holds only the *updates*); ``session_kw`` forwards to the
    follower's Session constructor, so a replica may run a different
    engine/device configuration than the leader — results are still
    bit-identical because every engine agrees with the set-evaluation
    semantics.
    """

    def __init__(self, graph, specs, wal_path, *, bucket: int = 8,
                 use_cache: bool = True, obs=None, **session_kw):
        self.path = os.fspath(wal_path)
        self.obs = obs if obs is not None else _obs.get_registry()
        self.session = Session(graph, specs, **session_kw)
        #: serving front end pinned behind the apply head (auto_flip off:
        #: publishing is the replica's explicit flip decision)
        self.service = WindowService(self.session, bucket=bucket,
                                     auto_flip=False, use_cache=use_cache,
                                     obs=self.obs)
        self._offset = 0  # byte offset of the next unread WAL record
        self.records_applied = 0
        self.polls = 0
        self._m_polls = self.obs.counter(
            "repro_replica_polls_total", "WAL tail polls")
        self._m_records = self.obs.counter(
            "repro_replica_records_total", "WAL records applied")
        self._g_lag_bytes = self.obs.gauge(
            "repro_replica_lag_bytes", "unapplied WAL bytes at last check")
        self._g_lag_versions = self.obs.gauge(
            "repro_replica_lag_versions",
            "applied-but-unpublished versions at last check")

    # ------------------------------------------------------------------ #
    def poll(self, upto_version: Optional[int] = None) -> int:
        """Apply newly appended WAL records to the follower's write head
        (readers stay pinned).  Returns the number applied.

        ``upto_version`` stops early — a replica can deliberately hold at
        a point-in-time version.  Unconsumed records stay unconsumed (the
        offset only advances past applied records), so a later poll
        resumes exactly there.
        """
        records, end = read_wal_records(self.path, self._offset)
        self.polls += 1
        self._m_polls.inc()
        if not records:
            self._offset = max(self._offset, end)
            return 0
        applied = 0
        stop_at = None
        for i, (version, batch) in enumerate(records):
            if upto_version is not None and version > upto_version:
                stop_at = i
                break
            self.session.update(batch)
            applied += 1
        if stop_at is None:
            self._offset = end
        else:
            # partial consumption: read_wal_records reports only the final
            # offset, so rescan the applied prefix for the byte boundary of
            # the first unapplied record
            self._offset = _offset_after(self.path, self._offset, stop_at)
        self.records_applied += applied
        self._m_records.inc(applied)
        return applied

    def flip(self) -> int:
        """Publish the apply head to readers (one snapshot swap)."""
        return self.service.flip()

    def catch_up(self) -> int:
        """Poll to the end of the log, then publish.  Returns the number
        of records applied."""
        n = self.poll()
        self.flip()
        return n

    # ------------------------------------------------------------------ #
    @property
    def version(self) -> int:
        """The published (reader-visible) version."""
        return self.service.version

    @property
    def head_version(self) -> int:
        """The applied-but-possibly-unpublished version."""
        return self.session.version

    @property
    def lag(self) -> Dict:
        """How far behind the log this replica is: unapplied bytes in the
        file plus unpublished versions at the head."""
        try:
            size = os.path.getsize(self.path)
        except OSError:
            size = 0
        behind = max(size - self._offset, 0)
        unpublished = self.session.version - self.service.version
        self._g_lag_bytes.set(behind)
        self._g_lag_versions.set(unpublished)
        return {
            "behind_bytes": behind,
            "unpublished_versions": unpublished,
            "published_version": self.service.version,
            "head_version": self.session.version,
        }

    # ------------------------------- reads ---------------------------- #
    def query(self, spec, vertex: Optional[int] = None, values=None):
        """Serve one read at the published version."""
        return self.service.query(spec, vertex=vertex, values=values)

    @property
    def stats(self) -> Dict:
        out = dict(self.service.stats)
        out.update(records_applied=self.records_applied, polls=self.polls,
                   lag=self.lag)
        return out


def _offset_after(path, offset: int, n_records: int) -> int:
    """Byte offset after the first ``n_records`` complete records past
    ``offset`` (0 = whole-file scan from the header)."""
    import zlib

    from repro.serve.wal import _FILE_MAGIC, _REC_HDR, _REC_MAGIC

    with open(path, "rb") as f:
        data = f.read()
    off = int(offset)
    if off == 0:
        off = len(_FILE_MAGIC)
    for _ in range(n_records):
        magic, _version, length, crc = _REC_HDR.unpack_from(data, off)
        if magic != _REC_MAGIC:
            break
        end = off + _REC_HDR.size + length
        if end > len(data) or zlib.crc32(data[off + _REC_HDR.size: end]
                                         ) & 0xFFFFFFFF != crc:
            break
        off = end
    return off
