"""Liveness/readiness for the serving tier, plus a zero-dependency endpoint.

:class:`HealthMonitor` folds every health signal the stack already
produces — staleness pressure, replica lag, SLO attainment, shadow-audit
verdicts, WAL-scrub status, flusher liveness — into one small state
machine:

* ``ready`` — every check passes; route traffic here.
* ``degraded`` — only *soft* checks fail (pressure, lag, SLO): the node
  is falling behind but its answers are still trusted.  Not ready (a
  router should prefer a ready peer) but recoverable without operator
  action.
* ``failed`` — a *hard* check fails: a quarantined correctness finding
  (oracle mismatch, scrub corruption, digest divergence) or a dead
  flusher thread.  Serving bytes whose correctness is in question is
  worse than serving nothing, so hard failures stay down until the
  findings are cleared (operator acknowledges / node is rebuilt).

:class:`HealthServer` exposes it over plain :mod:`http.server` (no
third-party deps — the container constraint), on an ephemeral port by
default:

* ``GET /metrics`` — Prometheus exposition text from the registry;
* ``GET /healthz`` — 200/503 + ``{"live": bool}`` (process liveness);
* ``GET /readyz`` — 200/503 + ``{"ready", "state", "failing": [...]}``;
* ``GET /debug``  — the service ``debug_report()`` + health + audit/scrub
  stats as JSON (the flight-recorder-and-everything dump).

Monitors register in a process-wide weak set (:func:`all_monitors`) so
the pytest failure hook can dump the last health report of every live
monitor alongside the metrics/trace/flight artifacts.
"""

from __future__ import annotations

import json
import threading
import time
import weakref
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, List, Optional, Sequence

from repro import obs as _obs

__all__ = ["HealthMonitor", "HealthServer", "all_monitors"]

_MONITORS: "weakref.WeakSet" = weakref.WeakSet()


def all_monitors() -> List["HealthMonitor"]:
    """Every live monitor in the process (weakly tracked)."""
    return list(_MONITORS)


class HealthMonitor:
    """Fold serving-stack signals into liveness/readiness.

    Every input is optional and duck-typed: ``service`` is a
    :class:`~repro.serve.window_service.WindowService` (or Async
    subclass), ``replicas`` are :class:`~repro.serve.replica.ReadReplica`
    objects, ``auditors`` / ``scrubbers`` come from
    :mod:`repro.obs.audit`.  :meth:`check` runs every check fresh and
    returns (and caches) a structured report.
    """

    #: checks whose failure means "falling behind" (degraded), not
    #: "answers untrusted" (failed)
    SOFT_CHECKS = ("pressure", "replica_lag", "slo", "fleet")

    def __init__(self, service=None, replicas: Sequence = (),
                 auditors: Sequence = (), scrubbers: Sequence = (),
                 cluster=None,
                 obs=None, max_pressure: float = 0.9,
                 max_lag_bytes: int = 1 << 20,
                 max_lag_versions: int = 64,
                 min_slo_attainment: float = 0.5,
                 min_slo_samples: int = 20):
        #: a :class:`~repro.serve.cluster.ReplicaSet`: the monitor then
        #: tracks its writer + live fleet (quorum) and ``/debug`` carries
        #: per-replica cursors and checkpoint state
        self.cluster = cluster
        if cluster is not None and service is None:
            service = cluster.writer
        self.service = service
        self.replicas = list(replicas)
        self.auditors = list(auditors)
        self.scrubbers = list(scrubbers)
        self.obs = obs if obs is not None else _obs.get_registry()
        self.max_pressure = float(max_pressure)
        self.max_lag_bytes = int(max_lag_bytes)
        self.max_lag_versions = int(max_lag_versions)
        self.min_slo_attainment = float(min_slo_attainment)
        self.min_slo_samples = int(min_slo_samples)
        self.state = "ready"
        self.last_report: Optional[Dict] = None
        self._g_ready = self.obs.gauge(
            "repro_health_ready", "1 when every readiness check passes")
        self._g_live = self.obs.gauge(
            "repro_health_live", "1 when the serving loop is alive")
        self._m_checks = self.obs.counter(
            "repro_health_checks_total", "health evaluations by state",
            labels=("state",))
        _MONITORS.add(self)

    # ------------------------------------------------------------------ #
    def check(self) -> Dict:
        """Evaluate every check; returns the structured report."""
        checks: Dict[str, Dict] = {}
        svc = self.service

        # liveness: a started-but-dead flusher thread means the serving
        # loop crashed out from under its queue
        live = True
        th = getattr(svc, "_thread", None) if svc is not None else None
        if th is not None and not th.is_alive() \
                and not getattr(svc, "_stopping", False):
            live = False
        checks["flusher"] = {"ok": live, "detail": (
            "flusher alive" if th is not None and live
            else "flusher thread died" if not live
            else "no background flusher (synchronous service)")}

        # soft: staleness pressure
        if svc is not None and hasattr(svc, "pressure"):
            p = float(svc.pressure())
            checks["pressure"] = {
                "ok": p <= self.max_pressure, "value": p,
                "detail": f"staleness pressure {p:.3f} "
                          f"(max {self.max_pressure})"}

        # soft: replica lag / hard: replica divergence.  Dead replicas are
        # not "lagging" — they are counted by the quorum check instead.
        replicas = (list(self.cluster.replicas.values())
                    if self.cluster is not None else self.replicas)
        live_reps = [r for r in replicas if getattr(r, "alive", True)]
        for i, rep in enumerate(replicas):
            if not getattr(rep, "alive", True):
                continue
            lag = rep.lag
            ok = (lag["behind_bytes"] <= self.max_lag_bytes
                  and lag["unpublished_versions"] <= self.max_lag_versions)
            checks[f"replica_lag[{i}]" if len(replicas) > 1
                   else "replica_lag"] = {
                "ok": ok, "value": lag,
                "detail": f"{lag['behind_bytes']}B behind, "
                          f"{lag['unpublished_versions']} unpublished"}
            div = getattr(rep, "divergence", None)
            if div is not None:
                checks[f"replica_divergence[{i}]"
                       if len(replicas) > 1
                       else "replica_divergence"] = {
                    "ok": False,
                    "detail": f"diverged at version {div.version} "
                              f"(wal offset {div.wal_offset}): {div.detail}"}

        # quorum over the fleet: hard-fail when the writer is down or a
        # majority of replicas is dead (no trustworthy capacity left);
        # a dead minority only degrades (soft "fleet" check)
        if replicas and (self.cluster is not None
                         or any(hasattr(r, "alive") for r in replicas)):
            n_live, n_total = len(live_reps), len(replicas)
            dead = [getattr(r, "name", str(i))
                    for i, r in enumerate(replicas)
                    if not getattr(r, "alive", True)]
            checks["quorum"] = {
                "ok": live and 2 * n_live > n_total,
                "value": {"live": n_live, "total": n_total},
                "detail": (f"{n_live}/{n_total} replicas live"
                           + ("" if live else "; writer down")
                           + (f"; dead: {dead}" if dead else ""))}
            if dead and 2 * n_live > n_total:
                checks["fleet"] = {
                    "ok": False, "value": dead,
                    "detail": f"minority down: {dead}"}

        # soft: SLO attainment (only once enough tickets scored)
        if svc is not None and getattr(svc, "slo", None) is not None \
                and getattr(self.obs, "enabled", False):
            worst, worst_cls, scored = 1.0, None, 0
            for cls, row in svc.slo.report().items():
                att = row.get("attainment")
                if att is None or row.get("ok", 0) < self.min_slo_samples:
                    continue
                scored += 1
                if att < worst:
                    worst, worst_cls = att, cls
            if scored:
                checks["slo"] = {
                    "ok": worst >= self.min_slo_attainment, "value": worst,
                    "detail": f"worst attainment {worst:.3f}"
                              + (f" ({worst_cls})" if worst_cls else "")}

        # hard: quarantined correctness findings
        mismatches = sum(a.mismatches for a in self.auditors)
        if self.auditors:
            checks["audit"] = {
                "ok": mismatches == 0, "value": mismatches,
                "detail": f"{mismatches} oracle mismatch(es) quarantined"}
        corruptions = sum(s.corruptions for s in self.scrubbers)
        if self.scrubbers:
            checks["scrub"] = {
                "ok": corruptions == 0, "value": corruptions,
                "detail": f"{corruptions} sealed-WAL corruption(s) found"}
        aud = getattr(svc, "auditor", None) if svc is not None else None
        if aud is not None and aud not in self.auditors:
            checks["audit"] = {
                "ok": aud.mismatches == 0, "value": aud.mismatches,
                "detail": f"{aud.mismatches} oracle mismatch(es) quarantined"}

        # fold into the state machine
        failing = [k for k, c in checks.items() if not c["ok"]]
        hard = [k for k in failing
                if not any(k.startswith(s) for s in self.SOFT_CHECKS)]
        if not live or hard:
            self.state = "failed"
        elif failing:
            self.state = "degraded"
        else:
            self.state = "ready"
        ready = self.state == "ready"
        self._g_ready.set(1 if ready else 0)
        self._g_live.set(1 if live else 0)
        self._m_checks.labels(self.state).inc()
        self.last_report = {
            "live": live,
            "ready": ready,
            "state": self.state,
            "failing": failing,
            "checks": checks,
            "t_unix_s": time.time(),
        }
        return self.last_report

    @property
    def ready(self) -> bool:
        """Readiness as of the last :meth:`check`."""
        return self.state == "ready"

    def report(self) -> Dict:
        """The last report (running a fresh check if there is none)."""
        return self.last_report if self.last_report is not None \
            else self.check()

    def debug_report(self) -> Dict:
        """Everything: health + service debug report + audit/scrub stats."""
        out: Dict = {"health": self.check()}
        if self.service is not None:
            try:
                out["service"] = self.service.debug_report()
            except Exception as e:  # debug must degrade, not 500
                out["service"] = {"error": repr(e)}
        if self.auditors:
            out["auditors"] = [a.stats for a in self.auditors]
        if self.scrubbers:
            out["scrubbers"] = [s.stats for s in self.scrubbers]
        if self.replicas:
            out["replicas"] = [r.stats for r in self.replicas]
        if self.cluster is not None:
            # per-replica lag + (segment, offset) cursors + checkpoint
            # retention — the cluster operator's one-stop dump
            try:
                out["cluster"] = self.cluster.debug_info()
            except Exception as e:  # debug must degrade, not 500
                out["cluster"] = {"error": repr(e)}
        return out


# ---------------------------------------------------------------------- #
#  HTTP endpoint (stdlib only)
# ---------------------------------------------------------------------- #
class HealthServer:
    """Serve a monitor over HTTP.  ``port=0`` binds an ephemeral port
    (read it back from :attr:`port` / :attr:`url` after :meth:`start`)."""

    def __init__(self, monitor: HealthMonitor, host: str = "127.0.0.1",
                 port: int = 0, registry=None):
        self.monitor = monitor
        self.host = host
        self._requested_port = int(port)
        self.registry = registry if registry is not None else monitor.obs
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ #
    @property
    def port(self) -> Optional[int]:
        return self._httpd.server_address[1] if self._httpd else None

    @property
    def url(self) -> Optional[str]:
        return f"http://{self.host}:{self.port}" if self._httpd else None

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> "HealthServer":
        if self.running:
            return self
        monitor, registry = self.monitor, self.registry

        class _Handler(BaseHTTPRequestHandler):
            def log_message(self, *a):  # quiet: health probes are chatty
                pass

            def _send(self, code: int, body: bytes, ctype: str) -> None:
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def _json(self, code: int, obj) -> None:
                self._send(code, json.dumps(obj, indent=2,
                                            default=str).encode(),
                           "application/json")

            def do_GET(self):  # noqa: N802  (http.server API)
                try:
                    path = self.path.split("?", 1)[0].rstrip("/") or "/"
                    if path == "/metrics":
                        text = (registry.prometheus()
                                if hasattr(registry, "prometheus") else "")
                        self._send(200, text.encode(),
                                   "text/plain; version=0.0.4")
                    elif path == "/healthz":
                        rep = monitor.check()
                        self._json(200 if rep["live"] else 503,
                                   {"live": rep["live"],
                                    "state": rep["state"]})
                    elif path == "/readyz":
                        rep = monitor.check()
                        self._json(200 if rep["ready"] else 503,
                                   {"ready": rep["ready"],
                                    "state": rep["state"],
                                    "failing": rep["failing"]})
                    elif path == "/debug":
                        self._json(200, monitor.debug_report())
                    else:
                        self._json(404, {"error": "not found", "routes": [
                            "/metrics", "/healthz", "/readyz", "/debug"]})
                except Exception as e:
                    try:
                        self._json(500, {"error": repr(e)})
                    except Exception:
                        pass

        self._httpd = ThreadingHTTPServer((self.host, self._requested_port),
                                          _Handler)
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="health-endpoint", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    def __enter__(self) -> "HealthServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
