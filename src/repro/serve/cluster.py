"""Replica cluster tier: one writer, N followers, a freshness router.

This module composes the serving stack's single-node pieces into the
scale-out topology the paper's workload implies (many concurrent readers
over one update stream)::

                         updates
                            │
                            ▼
                 writer AsyncWindowService ──► SegmentedWriteAheadLog
                  (append-before-apply)          (rotated GWAL1 segments)
                            │                      │        │
                     checkpoints ◄─ maybe_checkpoint        │ tail by
                  (repro.serve.checkpoint)                  │ (segment, offset)
                                               ┌────────────┴───────────┐
                                               ▼                        ▼
                                         ReadReplica r0  ...     ReadReplica rN-1
                                         (auto catch-up daemon, lag gauges)
                                               ▲                        ▲
                                               └──────── WindowRouter ──┘
                                            (freshness + per-class load,
                                             MVCC pinning, failover)

* :class:`ReplicaSet` owns the writer (an
  :class:`~repro.serve.window_service.AsyncWindowService` over a
  :class:`~repro.serve.wal.SegmentedWriteAheadLog`), the follower
  :class:`~repro.serve.replica.ReadReplica`s (each with a background
  auto-catch-up daemon and per-replica labeled lag gauges), periodic
  snapshot checkpoints, and *safe* segment truncation: a sealed segment
  is deleted only once every **live** replica's cursor and the newest
  checkpoint are past it, so no tailing cursor is ever stranded and
  checkpoint+tail recovery always finds a complete tail.  A killed
  replica rejoins through :meth:`ReplicaSet.rejoin` — checkpoint + tail,
  not its stale cursor — and is bitwise-equal to a fresh session at the
  head (the bit-identity invariant).

* :class:`WindowRouter` places reads: writes always go writer → WAL →
  followers; reads go to the **freshest** healthy replica (highest
  published version, optionally constrained by ``min_version`` for
  read-your-writes), tie-broken by least per-class in-flight load.  Each
  ticket is pinned to its replica's published MVCC version — a routed
  read is bitwise-identical to a direct ``Session.run`` replayed to that
  pinned version.  Failover never strands a waiter: when a replica is
  failed out, *exactly its* in-flight tickets get
  :class:`ReplicaFailedError` recorded (their submitters' ``get()``
  raises; nobody blocks forever) and subsequent traffic routes to the
  surviving replicas, falling back to the writer when none qualify.

Router and cluster metrics resolve the registry at call time (the obs
re-enable rule), so a cluster constructed before ``obs.enable()`` still
exports ``repro_router_*`` and per-replica lag after it.
"""

from __future__ import annotations

import os
import threading
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro import obs as _obs
from repro.core.api import Session
from repro.serve.checkpoint import latest_checkpoint, list_checkpoints
from repro.serve.replica import ReadReplica
from repro.serve.wal import SegmentedWriteAheadLog
from repro.serve.window_service import AsyncWindowService, Ticket

__all__ = ["ReplicaFailedError", "ReplicaSet", "RoutingError",
           "WindowRouter"]


class ReplicaFailedError(RuntimeError):
    """The replica serving this ticket was failed out of the cluster
    before the ticket was served.  Retry through the router — it will
    place the retry on a surviving replica."""


class RoutingError(RuntimeError):
    """No target can satisfy the routing constraints (e.g. ``min_version``
    newer than every published snapshot, including the writer's)."""


class ReplicaSet:
    """One writer + N followers sharing a segmented WAL + checkpoints.

    ``directory`` is the cluster's state root: ``wal/`` (rotated
    segments) and ``checkpoints/`` are created inside it.  ``graph`` and
    ``specs`` seed the writer and every base-built follower;
    ``session_kw`` forwards to each session constructor (both sides must
    match for bit-identical digests).

    ``checkpoint_every`` > 0 checkpoints the writer every that many
    versions (and, with ``truncate_on_checkpoint``, immediately drops the
    sealed segments nobody can ever need again).  Deterministic tests
    drive :meth:`update` / :meth:`sync` directly; live deployments call
    :meth:`start` for the flusher + auto-catch-up daemons.
    """

    def __init__(self, graph, specs, directory, *, n_replicas: int = 2,
                 bucket: int = 8, classes=None,
                 default_class: str = "interactive",
                 max_pending: int = 256,
                 rotate_bytes: int = 1 << 20,
                 rotate_records: Optional[int] = None,
                 fsync_every: int = 8,
                 checkpoint_every: int = 0,
                 truncate_on_checkpoint: bool = True,
                 wal_digests: bool = True,
                 replica_kw: Optional[Dict] = None,
                 obs=None, now_fn=None, **session_kw):
        self.directory = os.fspath(directory)
        self.wal_dir = os.path.join(self.directory, "wal")
        self.checkpoint_dir = os.path.join(self.directory, "checkpoints")
        os.makedirs(self.checkpoint_dir, exist_ok=True)
        self._obs_explicit = obs
        self._base_graph = graph
        self._specs = specs
        self._session_kw = dict(session_kw)
        self._replica_kw = dict(replica_kw or {})
        self._bucket = int(bucket)
        self.checkpoint_every = int(checkpoint_every)
        self.truncate_on_checkpoint = bool(truncate_on_checkpoint)
        self.wal = SegmentedWriteAheadLog(
            self.wal_dir, rotate_bytes=rotate_bytes,
            rotate_records=rotate_records, fsync_every=fsync_every,
            obs=obs)
        self.writer = AsyncWindowService(
            Session(graph, specs, **session_kw), bucket=bucket,
            classes=classes, default_class=default_class,
            max_pending=max_pending, wal=self.wal,
            wal_digests=wal_digests, obs=obs, now_fn=now_fn)
        self.replicas: Dict[str, ReadReplica] = {}
        for i in range(int(n_replicas)):
            self.add_replica(f"r{i}")
        found = latest_checkpoint(self.checkpoint_dir)
        self.last_checkpoint_version = found[0] if found else 0
        self.checkpoints_written = 0
        self.router = WindowRouter(self, obs=obs)

    # ------------------------------------------------------------------ #
    @property
    def obs(self):
        return (self._obs_explicit if self._obs_explicit is not None
                else _obs.get_registry())

    @property
    def version(self) -> int:
        """The writer's head version."""
        return self.writer.session.version

    @property
    def live_replicas(self) -> Dict[str, ReadReplica]:
        return {n: r for n, r in self.replicas.items() if r.alive}

    def add_replica(self, name: Optional[str] = None,
                    **kw) -> ReadReplica:
        """Grow the fleet: a follower built from the base graph that will
        tail the whole retained log (use :meth:`rejoin` to come up from a
        checkpoint instead)."""
        if name is None:
            name = f"r{len(self.replicas)}"
        merged = {**self._session_kw, **self._replica_kw, **kw}
        rep = ReadReplica(self._base_graph, self._specs, self.wal_dir,
                          bucket=self._bucket, name=name,
                          obs=self._obs_explicit, **merged)
        self.replicas[name] = rep
        return rep

    # --------------------------- write path ---------------------------- #
    def update(self, batch) -> Dict:
        """Writer → WAL → (followers tail): apply one batch at the writer
        and run the checkpoint/truncation policy."""
        report = self.writer.update(batch)
        self.maybe_checkpoint()
        return report

    def checkpoint(self) -> Tuple[int, str]:
        """Snapshot the writer now; returns ``(version, path)``."""
        version, path = self.writer.session.save_checkpoint(
            self.checkpoint_dir)
        self.last_checkpoint_version = version
        self.checkpoints_written += 1
        if self.truncate_on_checkpoint:
            self.truncate()
        return version, path

    def maybe_checkpoint(self) -> Optional[Tuple[int, str]]:
        """Checkpoint iff ``checkpoint_every`` versions have passed."""
        if self.checkpoint_every <= 0:
            return None
        if self.version - self.last_checkpoint_version \
                < self.checkpoint_every:
            return None
        return self.checkpoint()

    def safe_truncate_version(self) -> int:
        """The newest version whose history nobody can ever need again:
        ``min(newest checkpoint, slowest *live* replica's applied
        version)``.  Dead replicas are excluded — they rejoin via
        checkpoint + tail, never via their stale cursor.  0 (nothing
        truncatable) until a checkpoint exists: full-replay recovery
        needs the whole log."""
        if self.last_checkpoint_version <= 0:
            return 0
        safe = self.last_checkpoint_version
        for rep in self.live_replicas.values():
            safe = min(safe, rep.head_version)
        return safe

    def truncate(self) -> List[Tuple[int, str]]:
        """Drop sealed segments wholly below :meth:`safe_truncate_version`."""
        return self.wal.truncate_upto(self.safe_truncate_version())

    # --------------------------- follower path -------------------------- #
    def catch_up(self) -> Dict[str, int]:
        """Poll + publish every live replica (deterministic stepping for
        tests; live deployments run the tail daemons instead)."""
        return {name: rep.catch_up()
                for name, rep in self.live_replicas.items()}

    def sync(self) -> Dict[str, int]:
        """Flush the WAL group commit, then catch every follower up."""
        self.wal.sync()
        return self.catch_up()

    # --------------------------- lifecycle ------------------------------ #
    def start(self, tail_interval_s: float = 0.05) -> "ReplicaSet":
        """Start the writer's flusher and every follower's tail daemon."""
        self.writer.start()
        for rep in self.live_replicas.values():
            rep.start_tailing(interval_s=tail_interval_s)
        return self

    def stop(self) -> None:
        for rep in self.replicas.values():
            rep.stop_tailing()
        self.writer.stop(drain=True)

    def close(self) -> None:
        self.stop()
        self.writer.close()

    def __enter__(self) -> "ReplicaSet":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # --------------------------- fault handling ------------------------- #
    def kill(self, name: str) -> int:
        """Fault-inject/retire one replica: stop its daemon, mark it dead,
        and fail over its in-flight tickets.  Returns the number of
        tickets failed over."""
        rep = self.replicas[name]
        rep.kill()
        return self.router.fail_replica(name)

    def rejoin(self, name: str, catch_up: bool = True) -> ReadReplica:
        """Bring a killed (or brand-new) replica back through **checkpoint
        + tail**: rebuild from the newest checkpoint, seek the cursor past
        it, replay only the bounded tail, and return to routing.  Falls
        back to a base-graph build when no checkpoint exists yet."""
        merged = {**self._session_kw, **self._replica_kw}
        if latest_checkpoint(self.checkpoint_dir) is not None:
            rep = ReadReplica.from_checkpoint(
                self._specs, self.wal_dir, self.checkpoint_dir,
                name=name, bucket=self._bucket, obs=self._obs_explicit,
                **merged)
        else:
            rep = ReadReplica(self._base_graph, self._specs, self.wal_dir,
                              bucket=self._bucket, name=name,
                              obs=self._obs_explicit, **merged)
        self.replicas[name] = rep
        if catch_up:
            self.wal.sync()
            rep.catch_up()
        self.router.restore_replica(name)
        return rep

    # ------------------------------------------------------------------ #
    def debug_info(self) -> Dict:
        """Per-replica lag/cursor/liveness + WAL segments + checkpoint
        state (the ``/debug`` payload for the cluster)."""
        return {
            "writer": {
                "version": self.version,
                "running": self.writer.running,
            },
            "replicas": {
                name: {
                    "alive": rep.alive,
                    "tailing": rep.tailing,
                    "lag": rep.lag,
                    "cursor": rep.cursor,
                    "published_version": rep.version,
                    "head_version": rep.head_version,
                    "diverged": rep.divergence is not None,
                    "restored_from_version": rep.restored_from_version,
                } for name, rep in self.replicas.items()
            },
            "wal": self.wal.stats,
            "checkpoints": {
                "last_version": self.last_checkpoint_version,
                "written": self.checkpoints_written,
                "retained": [v for v, _ in
                             list_checkpoints(self.checkpoint_dir)],
            },
            "router": self.router.stats,
        }

    @property
    def stats(self) -> Dict:
        return self.debug_info()


# ---------------------------------------------------------------------- #
class WindowRouter:
    """Route reads across a replica fleet by freshness + per-class load.

    Construct over a :class:`ReplicaSet` (the usual way — the set already
    owns one at ``.router``) or over explicit ``replicas`` (a
    ``{name: ReadReplica}`` dict) + ``writer``.  Placement:

    1. candidates = live, un-failed, un-diverged replicas whose
       *published* version satisfies ``min_version`` (when given);
    2. keep only the freshest (highest published version);
    3. least per-class in-flight load wins (ties: lexical name — stable).

    With no candidate the read falls back to the **writer's** service
    (always at the head); if even the writer cannot satisfy
    ``min_version``, :class:`RoutingError`.  Writes are *not* routed:
    they always go through the writer (``ReplicaSet.update``).
    """

    def __init__(self, replica_set: Optional[ReplicaSet] = None, *,
                 replicas: Optional[Dict[str, ReadReplica]] = None,
                 writer=None, obs=None):
        if replica_set is None and replicas is None:
            raise ValueError("need a ReplicaSet or an explicit replica map")
        self._set = replica_set
        self._replicas = replicas
        self.writer = writer if writer is not None else (
            replica_set.writer if replica_set is not None else None)
        self._obs_explicit = obs
        self._lock = threading.Lock()
        # Tickets compare by value (dataclass) so track them by identity
        self._inflight: Dict[Optional[str], Dict[int, Ticket]] = {}
        self._class_load: Dict[Tuple[Optional[str], str], int] = {}
        self.failed: Set[str] = set()
        self.routed = 0
        self.failovers = 0
        self.failed_tickets = 0

    # ------------------------------------------------------------------ #
    @property
    def obs(self):
        """Registry resolved at call time (the obs re-enable rule)."""
        return (self._obs_explicit if self._obs_explicit is not None
                else _obs.get_registry())

    def targets(self) -> Dict[str, ReadReplica]:
        return (self._set.replicas if self._set is not None
                else self._replicas)

    def _candidates(self, min_version: Optional[int]
                    ) -> Dict[str, ReadReplica]:
        out = {}
        for name, rep in self.targets().items():
            if not rep.alive or name in self.failed \
                    or rep.divergence is not None:
                continue
            if min_version is not None and rep.version < min_version:
                continue
            out[name] = rep
        return out

    def pick(self, request_class: str = "point",
             min_version: Optional[int] = None) -> Optional[str]:
        """The chosen replica name, or None for writer fallback."""
        cands = self._candidates(min_version)
        if not cands:
            return None
        freshest = max(rep.version for rep in cands.values())
        pool = sorted(n for n, rep in cands.items()
                      if rep.version == freshest)
        with self._lock:
            return min(pool, key=lambda n: (
                self._class_load.get((n, request_class), 0), n))

    # ------------------------------------------------------------------ #
    def _track(self, t: Ticket, name: Optional[str], cls: str) -> None:
        t._route_target = name
        t._route_class = cls
        with self._lock:
            self._inflight.setdefault(name, {})[id(t)] = t
            key = (name, cls)
            self._class_load[key] = self._class_load.get(key, 0) + 1
        self.routed += 1
        self.obs.counter(
            "repro_router_requests_total", "reads placed by the router",
            labels=("target", "cls")).labels(name or "writer", cls).inc()

    def _untrack(self, t: Ticket) -> None:
        # caller holds self._lock
        key = (getattr(t, "_route_target", None),
               getattr(t, "_route_class", None))
        n = self._class_load.get(key, 0)
        if n > 1:
            self._class_load[key] = n - 1
        else:
            self._class_load.pop(key, None)

    def prune(self) -> None:
        """Drop finished tickets from the in-flight accounting."""
        with self._lock:
            for name, ts in self._inflight.items():
                done = [k for k, t in ts.items() if t.done]
                for k in done:
                    self._untrack(ts.pop(k))

    def inflight(self, name: Optional[str] = None) -> int:
        self.prune()
        with self._lock:
            if name is not None:
                return len(self._inflight.get(name, ()))
            return sum(len(ts) for ts in self._inflight.values())

    # ------------------------------------------------------------------ #
    def submit(self, spec, vertex: Optional[int] = None, values=None,
               request_class: str = "point",
               min_version: Optional[int] = None,
               target: Optional[str] = None) -> Ticket:
        """Place one read; returns its ticket (served on the next
        :meth:`flush` of its target, or by the target's own flusher).
        The ticket's ``version`` is pinned to the serving snapshot's
        published version at flush time.  ``target`` forces placement
        (tests / sticky sessions)."""
        name = target if target is not None \
            else self.pick(request_class, min_version)
        if name is None:
            if self.writer is None:
                raise RoutingError("no replica qualifies and no writer "
                                   "to fall back to")
            if min_version is not None \
                    and self.writer.version < min_version:
                raise RoutingError(
                    f"min_version {min_version} is newer than every "
                    f"published snapshot (writer at {self.writer.version})")
            t = self.writer.submit(spec, vertex=vertex, values=values,
                                   request_class=request_class)
        else:
            rep = self.targets()[name]
            if not rep.alive or name in self.failed:
                raise ReplicaFailedError(f"replica {name!r} is failed out")
            t = rep.service.submit(spec, vertex=vertex, values=values)
        self._track(t, name, request_class)
        return t

    def flush(self) -> int:
        """Flush every live target with queued work (and the writer).
        Returns the number of tickets served."""
        served = 0
        for name, rep in list(self.targets().items()):
            if not rep.alive or name in self.failed:
                continue
            if rep.service._pending:
                served += len(rep.service.flush("router"))
        if self.writer is not None and self.writer._pending \
                and not self.writer.running:
            served += len(self.writer.flush("router"))
        self.prune()
        return served

    def query(self, spec, vertex: Optional[int] = None, values=None,
              request_class: str = "point",
              min_version: Optional[int] = None,
              timeout: Optional[float] = 30.0):
        """Submit + flush + get: one routed read, served at its target's
        pinned published version."""
        t = self.submit(spec, vertex=vertex, values=values,
                        request_class=request_class,
                        min_version=min_version)
        self.flush()
        return t.get(timeout=timeout)

    # --------------------------- failover ------------------------------ #
    def fail_replica(self, name: str, error: Optional[str] = None) -> int:
        """Take ``name`` out of rotation and fail over **exactly its**
        in-flight tickets: each gets :class:`ReplicaFailedError` recorded
        and its waiter released (submitters retry through the router; the
        other replicas' tickets are untouched).  Returns the number of
        tickets failed."""
        self.failed.add(name)
        rep = self.targets().get(name)
        victims: Dict[int, Ticket] = {}
        if rep is not None:
            victims.update((id(t), t) for t in rep.service._take_pending())
        with self._lock:
            tracked = self._inflight.pop(name, {})
            for t in tracked.values():
                self._untrack(t)
        victims.update((k, t) for k, t in tracked.items() if not t.done)
        n_failed = 0
        for t in victims.values():
            if t.done:
                continue
            t.error = ReplicaFailedError(
                error or f"replica {name!r} failed before serving "
                         f"ticket {t.rid}")
            if t._span is not None:
                t._span.set(ok=False, failover=True).finish()
            t._finish()
            n_failed += 1
        self.failovers += 1
        self.failed_tickets += n_failed
        reg = self.obs
        reg.counter("repro_router_failovers_total",
                    "replicas failed out of rotation").inc()
        reg.counter("repro_router_failover_tickets_total",
                    "in-flight tickets failed by a replica failover"
                    ).inc(n_failed)
        return n_failed

    def restore_replica(self, name: str) -> None:
        """Return a (rejoined) replica to the candidate pool."""
        self.failed.discard(name)

    # ------------------------------------------------------------------ #
    @property
    def stats(self) -> Dict:
        self.prune()
        with self._lock:
            inflight = {name or "writer": len(ts)
                        for name, ts in self._inflight.items() if ts}
            load = {f"{name or 'writer'}/{cls}": n
                    for (name, cls), n in self._class_load.items()}
        for name, n in inflight.items():
            self.obs.gauge("repro_router_inflight",
                           "in-flight routed tickets", labels=("target",)
                           ).labels(name).set(n)
        return {
            "routed": self.routed,
            "failovers": self.failovers,
            "failed_tickets": self.failed_tickets,
            "failed_out": sorted(self.failed),
            "inflight": inflight,
            "class_load": load,
        }
