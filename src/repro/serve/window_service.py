"""Window-analytics serving layer: scheduler + versioned reads + result cache.

The paper's index makes ONE window query ~1e4x faster; this layer turns
that into a *service*: many concurrent callers issuing point-vertex and
full-graph reads against a live update stream, without blocking reads on
writes and without ever recompiling the fused executables.  It fronts a
:class:`repro.core.api.Session` (or ``Session(mesh=...)`` for a sharded
runtime) with three mechanisms:

* **Micro-batching scheduler** — requests queue in :meth:`WindowService.
  submit` and :meth:`~WindowService.flush` coalesces them per (window,
  attr) plan group into padded ``run_many`` launches at a fixed batch
  bucket.  Same scale posture as :class:`repro.serve.engine.ServeEngine`'s
  slot design: the [bucket, n] batch never reshapes, so the vmapped fused
  executable compiles once and every flush replays it (zero retraces —
  ``repro.core.api.run_many_cache_size`` is the counter).

* **Versioned snapshot reads** — session state (graph, indices, plans) is
  immutable and :meth:`Session.snapshot` captures it atomically.  The
  service keeps one *active* :class:`~repro.core.api.SessionView` for
  readers; :meth:`~WindowService.update` streams batches into the write
  head (building version v+1 artifacts by incremental patching) while
  reads keep answering at the pinned version v, and
  :meth:`~WindowService.flip` publishes v+1 with one reference swap —
  reader-side MVCC, so no query ever observes a half-patched plan.

* **Affected-owner result cache** — :class:`AffectedOwnerCache` holds one
  full result vector per (window, agg, attr) at vertex granularity.  An
  update invalidates ONLY the affected-owner set the batched index
  maintenance already computed (paper §4.3's locality: every other
  vertex's window provably did not change), so steady-state point traffic
  is an O(1) hit and an update costs ~|affected| invalidations instead of
  a full cache flush.  The first post-update miss refreshes the whole
  group vector with one fused launch.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.api import QuerySpec, Session


# ---------------------------------------------------------------------- #
#  Tickets
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class Ticket:
    """One submitted request, completed by the flush that serves it.

    ``result`` is a scalar for point reads ([n] vector for full-graph
    reads); ``version`` is the snapshot version the answer was computed at
    (the pinned read version — not necessarily the write head).
    """

    rid: int
    spec_index: int
    vertex: Optional[int]
    values: Optional[np.ndarray]
    submitted_s: float
    result: Optional[object] = None
    version: Optional[int] = None
    cache_hit: bool = False
    latency_s: float = 0.0

    @property
    def done(self) -> bool:
        return self.result is not None


# ---------------------------------------------------------------------- #
#  Affected-owner result cache
# ---------------------------------------------------------------------- #
class AffectedOwnerCache:
    """Vertex-level result cache invalidated by affected-owner sets.

    One entry per compiled plan group: the fused query's full result
    vectors (``{agg: [n]}``) plus a per-vertex validity mask.
    :meth:`on_update` clears ONLY the affected owners' bits — their
    windows are the only ones whose membership changed, so every other
    cached aggregate is still exact; groups without incremental state
    (no index to bound the blast radius) are dropped wholesale.

    Reads and writes are version-gated: entries are valid at
    :attr:`version` (advanced by ``on_update``), and a reader or writer
    pinned at any other version bypasses the cache instead of polluting
    it — that is what lets the serving layer keep reads pinned behind the
    write head (``auto_flip=False``) without ever serving stale hits.
    """

    def __init__(self):
        self.version = 0
        self._entries: Dict[int, Dict] = {}
        self.hits = 0
        self.misses = 0
        self.invalidated = 0  # per-vertex invalidations applied
        self.full_drops = 0  # whole entries dropped (stateless groups)

    def bind(self, session) -> None:
        """Called by :meth:`Session.attach_cache`."""
        self.version = session.version

    # ------------------------------- reads ---------------------------- #
    def get_group(self, gi: int, version: int):
        """Full vectors of group ``gi`` if entirely valid at ``version``."""
        e = self._entries.get(gi)
        if version != self.version or e is None or not e["valid_all"]:
            self.misses += 1
            return None
        self.hits += 1
        return {a: v.copy() for a, v in e["vectors"].items()}

    def get_point(self, gi: int, agg: str, vertex: int, version: int):
        """Cached aggregate of one vertex, or None on miss/stale.

        Not counted in :attr:`hits`/:attr:`misses` — those track
        full-vector group reads (refresh dedup); a point miss always falls
        through to a group read, so counting both would double-book it.
        The service keeps its own point-level counters.
        """
        e = self._entries.get(gi)
        if version != self.version or e is None or not e["valid"][vertex]:
            return None
        return e["vectors"][agg][vertex]

    # ------------------------------- writes --------------------------- #
    def put_group(self, gi: int, version: int, vectors: Dict) -> None:
        if version != self.version:
            return  # writer pinned behind the head: do not pollute
        vecs = {a: np.array(v) for a, v in vectors.items()}
        n = len(next(iter(vecs.values())))
        self._entries[gi] = {
            "vectors": vecs,
            "valid": np.ones(n, dtype=bool),
            "valid_all": True,
        }

    def on_update(self, version: int, owner_map: Dict) -> None:
        """Advance to ``version``.  ``owner_map[gi]`` is the group's
        affected-owner array, or None when the group has no incremental
        state (nothing bounds its staleness — drop the entry)."""
        for gi, owners in owner_map.items():
            e = self._entries.get(gi)
            if e is None:
                continue
            if owners is None:
                del self._entries[gi]
                self.full_drops += 1
                continue
            owners = np.asarray(owners, np.int64)
            e["valid"][owners] = False
            e["valid_all"] = bool(e["valid"].all())
            self.invalidated += int(owners.size)
        self.version = version

    # ------------------------------------------------------------------ #
    def valid_fraction(self, gi: int) -> float:
        e = self._entries.get(gi)
        return float(e["valid"].mean()) if e is not None else 0.0

    @property
    def stats(self) -> Dict:
        total = self.hits + self.misses
        return {
            "version": self.version,
            "entries": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / max(total, 1),
            "invalidated": self.invalidated,
            "full_drops": self.full_drops,
        }


# ---------------------------------------------------------------------- #
#  WindowService
# ---------------------------------------------------------------------- #
class WindowService:
    """Micro-batched, versioned, cached front end over a Session.

    ``bucket`` fixes the padded batch size of coalesced explicit-values
    launches (the executable-reuse contract); ``auto_flip`` publishes every
    update to readers immediately (turn it off to pin readers at a version
    while a burst of updates lands, then :meth:`flip` once).

    Request model: :meth:`submit` enqueues and returns a :class:`Ticket`;
    :meth:`flush` serves everything pending against the active snapshot;
    :meth:`query` is submit+flush for one-call convenience.  A request
    names a compiled spec (index or the ``QuerySpec`` itself), optionally a
    ``vertex`` (point read) and optionally an explicit ``values`` vector
    (evaluate the spec's window under substitute attribute values — the
    classic serving pattern where each caller brings its own features).
    """

    def __init__(self, session: Session, bucket: int = 8,
                 auto_flip: bool = True, use_cache: bool = True):
        self.session = session
        self.bucket = int(bucket)
        assert self.bucket >= 1
        self.auto_flip = auto_flip
        self.cache = AffectedOwnerCache() if use_cache else None
        if self.cache is not None:
            session.attach_cache(self.cache)
        self._active = session.snapshot()
        self._pending: List[Ticket] = []
        self._rid = 0
        self._spec_index = {s: i for i, s in enumerate(session.compiled.specs)}
        # telemetry
        self.flushes = 0
        self.batched_launches = 0
        self.padded_rows = 0
        self.served = 0
        self.point_hits = 0
        self.point_misses = 0

    # ------------------------------------------------------------------ #
    @property
    def version(self) -> int:
        """The pinned read version (what queries answer at)."""
        return self._active.version

    @property
    def head_version(self) -> int:
        """The write head (latest applied update)."""
        return self.session.version

    # ------------------------------------------------------------------ #
    def _resolve(self, spec) -> int:
        if isinstance(spec, (int, np.integer)):
            if not 0 <= int(spec) < len(self.session.compiled.specs):
                raise IndexError(f"spec index {spec} out of range")
            return int(spec)
        if not isinstance(spec, QuerySpec):
            raise TypeError(f"spec must be an int index or QuerySpec, "
                            f"got {spec!r}")
        if spec not in self._spec_index:
            raise KeyError(
                f"{spec} is not compiled into this session; compiled specs: "
                f"{list(self.session.compiled.specs)}"
            )
        return self._spec_index[spec]

    def submit(self, spec, vertex: Optional[int] = None,
               values=None) -> Ticket:
        """Enqueue one request; returns its (unfilled) :class:`Ticket`.

        Everything is validated here, not at flush time — one malformed
        request must fail its own submit, never poison a whole coalesced
        flush of other callers' tickets."""
        si = self._resolve(spec)
        n = self.session.graph.n
        if vertex is not None:
            vertex = int(vertex)
            if not 0 <= vertex < n:
                raise IndexError(f"vertex {vertex} out of range [0, {n})")
        if values is not None:
            # f32 conversion here: a non-numeric vector must fail its own
            # submit, not blow up mid-flush (the executors cast to f32
            # anyway, so results are unchanged).  np.array (not asarray)
            # so a caller reusing one scratch buffer between submit and
            # flush cannot mutate an already-queued request.
            values = np.array(values, np.float32)
            if values.shape != (n,):
                raise ValueError(
                    f"per-request values must have shape ({n},), "
                    f"got {values.shape}"
                )
        t = Ticket(
            rid=self._rid, spec_index=si, vertex=vertex,
            values=values, submitted_s=time.perf_counter(),
        )
        self._rid += 1
        self._pending.append(t)
        return t

    def query(self, spec, vertex: Optional[int] = None, values=None):
        """Submit + flush; returns the result directly."""
        t = self.submit(spec, vertex=vertex, values=values)
        self.flush()
        return t.result

    # ------------------------------------------------------------------ #
    def _serve_snapshot(self, view, gi: int, agg: str,
                        vertex: Optional[int], memo: Dict):
        """Current-attribute read through the affected-owner cache.

        ``memo`` holds group vectors already computed *this flush*: when
        the versioned cache cannot serve (``use_cache=False``, or a reader
        pinned behind the write head bypassing it), N point reads of one
        group still cost one fused launch, not N.
        """
        if self.cache is not None and vertex is not None:
            hit = self.cache.get_point(gi, agg, vertex, view.version)
            if hit is not None:
                self.point_hits += 1
                return hit, True
            self.point_misses += 1
        # miss (or full read): one fused launch refreshes the whole group
        # vector — in the cache (cache-aware run_group) and the flush memo
        out = memo.get(gi)
        if out is None:
            out = memo[gi] = view.run_group(gi)
        vec = out[agg]
        # full reads copy at the ticket boundary: several tickets may share
        # one memo/cache vector, and a caller mutating its result must not
        # corrupt another caller's answer
        return (vec[vertex] if vertex is not None else vec.copy()), False

    def flush(self) -> List[Ticket]:
        """Serve every pending request against the active snapshot.

        Current-state requests (``values=None``) ride the affected-owner
        cache — point reads are O(1) hits in steady state.  Explicit-values
        requests coalesce per plan group into ``ceil(B / bucket)`` padded
        ``run_many`` launches, so requests for *different* aggregates of
        one (window, attr) group share a launch (they are channels of the
        same fused plan) and the [bucket, n] executable never retraces.
        """
        pending, self._pending = self._pending, []
        if not pending:
            return pending
        view = self._active
        groups = self.session.compiled.groups
        slots = self.session.compiled.spec_slots
        by_group: Dict[int, List[Ticket]] = {}
        memo: Dict[int, Dict] = {}  # group vectors computed this flush
        for t in pending:
            gi, ai = slots[t.spec_index]
            if t.values is None:
                t.result, t.cache_hit = self._serve_snapshot(
                    view, gi, groups[gi].aggs[ai], t.vertex, memo
                )
                t.version = view.version
            else:
                by_group.setdefault(gi, []).append(t)
        n = view.graph.n
        for gi, reqs in by_group.items():
            grp = groups[gi]
            # padding buys executable reuse only on the jitted batched
            # device paths; a host group would pay one full sequential
            # query per pad row for nothing.  artifacts[gi] holds one
            # (index, plan) pair per materialized term (composite windows
            # on the algebraic fast path carry several).
            pad = (
                self.session.registry.capability(grp.engine).device
                and any(p is not None for _, p in view.artifacts[gi])
            )
            for lo in range(0, len(reqs), self.bucket):
                chunk = reqs[lo: lo + self.bucket]
                rows_n = self.bucket if pad else len(chunk)
                vb = np.zeros((rows_n, n), np.float32)  # fixed bucket
                for row, t in enumerate(chunk):
                    vb[row] = t.values
                out = view.run_group_many(gi, vb)
                self.batched_launches += 1
                self.padded_rows += rows_n - len(chunk)
                for row, t in enumerate(chunk):
                    _, ai = slots[t.spec_index]
                    vec = out[grp.aggs[ai]][row]
                    t.result = (vec[t.vertex] if t.vertex is not None
                                else np.asarray(vec))
                    t.version = view.version
        now = time.perf_counter()
        for t in pending:
            t.latency_s = now - t.submitted_s
        self.flushes += 1
        self.served += len(pending)
        return pending

    # ------------------------------------------------------------------ #
    def update(self, batch) -> Dict:
        """Stream one UpdateBatch into the write head.

        Readers keep the active snapshot until :meth:`flip` (automatic
        when ``auto_flip``).  The session invalidates the attached cache
        for exactly the batch's affected-owner sets; version gating means
        a reader still pinned behind the head simply bypasses the cache
        rather than ever seeing version-v+1 data at version v.
        """
        reports = self.session.update(batch)
        if self.auto_flip:
            self.flip()
        return reports

    def flip(self) -> int:
        """Atomically publish the newest version to readers: one reference
        swap of an immutable snapshot (no reader ever holds a half-patched
        plan — it holds either the old view or the new one)."""
        self._active = self.session.snapshot()
        return self._active.version

    # ------------------------------------------------------------------ #
    @property
    def stats(self) -> Dict:
        point = self.point_hits + self.point_misses
        out = {
            "served": self.served,
            "flushes": self.flushes,
            "batched_launches": self.batched_launches,
            "padded_rows": self.padded_rows,
            "bucket": self.bucket,
            "active_version": self._active.version,
            "head_version": self.session.version,
            "point_hits": self.point_hits,
            "point_misses": self.point_misses,
            "point_hit_rate": self.point_hits / max(point, 1),
        }
        if self.cache is not None:
            out["cache"] = self.cache.stats
        return out
