"""Window-analytics serving layer: scheduler + versioned reads + result cache.

The paper's index makes ONE window query ~1e4x faster; this layer turns
that into a *service*: many concurrent callers issuing point-vertex and
full-graph reads against a live update stream, without blocking reads on
writes and without ever recompiling the fused executables.  It fronts a
:class:`repro.core.api.Session` (or ``Session(mesh=...)`` for a sharded
runtime) with three mechanisms:

* **Micro-batching scheduler** — requests queue in :meth:`WindowService.
  submit` and :meth:`~WindowService.flush` coalesces them per (window,
  attr) plan group into padded ``run_many`` launches at a fixed batch
  bucket.  Same scale posture as :class:`repro.serve.engine.ServeEngine`'s
  slot design: the [bucket, n] batch never reshapes, so the vmapped fused
  executable compiles once and every flush replays it (zero retraces —
  ``repro.core.api.run_many_cache_size`` is the counter).

* **Versioned snapshot reads** — session state (graph, indices, plans) is
  immutable and :meth:`Session.snapshot` captures it atomically.  The
  service keeps one *active* :class:`~repro.core.api.SessionView` for
  readers; :meth:`~WindowService.update` streams batches into the write
  head (building version v+1 artifacts by incremental patching) while
  reads keep answering at the pinned version v, and
  :meth:`~WindowService.flip` publishes v+1 with one reference swap —
  reader-side MVCC, so no query ever observes a half-patched plan.

* **Affected-owner result cache** — :class:`AffectedOwnerCache` holds one
  full result vector per (window, agg, attr) at vertex granularity.  An
  update invalidates ONLY the affected-owner set the batched index
  maintenance already computed (paper §4.3's locality: every other
  vertex's window provably did not change), so steady-state point traffic
  is an O(1) hit and an update costs ~|affected| invalidations instead of
  a full cache flush.  The first post-update miss refreshes the whole
  group vector with one fused launch.

:class:`AsyncWindowService` adds the continuous-batching front end on
top: a background flusher launches a bucket when it *fills* or when the
earliest request's latency **deadline** expires (``max_delay_ms`` per
:class:`RequestClass`); admission control sheds the lowest-priority
sheddable full-graph scans first (never point reads) and applies
backpressure otherwise, with the admission window shrinking as the
session's staleness approaches the :class:`~repro.core.streaming.
StalenessPolicy` thresholds; and every update is appended to a
:class:`~repro.serve.wal.WriteAheadLog` *before* it is applied, so a
crash recovers by replay (:meth:`~repro.core.api.Session.
restore_from_wal`) and a follower tailing the log is a read replica
(:class:`~repro.serve.replica.ReadReplica`).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Union

import numpy as np

from repro import obs as _obs
from repro.core.api import QuerySpec, Session, record_recompiles
from repro.obs.slo import SLOTracker
from repro.serve.flight import FlightRecorder


class LoadShedError(RuntimeError):
    """The request was rejected (or evicted) by admission control."""


# ---------------------------------------------------------------------- #
#  Request classes
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class RequestClass:
    """Latency/priority contract of a request.

    ``max_delay_ms`` is the continuous-batching deadline: a pending
    request is launched no later than this after submit, even in a
    partially filled bucket.  ``priority`` orders load shedding (lower
    sheds first).  ``sheddable`` marks requests admission control may
    reject under overload; point reads are *never* shed regardless (they
    are O(1) cache hits in steady state — shedding them buys nothing).
    """

    name: str
    max_delay_ms: float = 5.0
    priority: int = 10
    sheddable: bool = True


DEFAULT_REQUEST_CLASSES = {
    "point": RequestClass("point", max_delay_ms=2.0, priority=100,
                          sheddable=False),
    "interactive": RequestClass("interactive", max_delay_ms=5.0, priority=10),
    "batch": RequestClass("batch", max_delay_ms=50.0, priority=0),
}


# ---------------------------------------------------------------------- #
#  Tickets
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class Ticket:
    """One submitted request, completed (or failed) by the flush that
    serves it — a future.

    ``result`` is a scalar for point reads ([n] vector for full-graph
    reads); ``version`` is the snapshot version the answer was computed at
    (the pinned read version — not necessarily the write head).  A flush
    that raises mid-launch records the exception on ``error`` for exactly
    the affected tickets; :meth:`get` re-raises it in the submitter.
    """

    rid: int
    spec_index: int
    vertex: Optional[int]
    values: Optional[np.ndarray]
    submitted_s: float
    result: Optional[object] = None
    version: Optional[int] = None
    cache_hit: bool = False
    latency_s: float = 0.0
    error: Optional[BaseException] = None
    request_class: Optional[RequestClass] = None
    deadline_s: Optional[float] = None
    _event: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False, compare=False)
    _span: Optional[object] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def class_name(self) -> str:
        return self.request_class.name if self.request_class else "default"

    @property
    def done(self) -> bool:
        return self._event.is_set()

    @property
    def failed(self) -> bool:
        return self.error is not None

    @property
    def priority(self) -> int:
        return self.request_class.priority if self.request_class else 10

    def _finish(self) -> None:
        self._event.set()

    def get(self, timeout: Optional[float] = None):
        """Block until served; return the result or re-raise the recorded
        error (``LoadShedError`` if admission control evicted it)."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"ticket {self.rid} not served "
                               f"within {timeout}s")
        if self.error is not None:
            raise self.error
        return self.result


# ---------------------------------------------------------------------- #
#  Affected-owner result cache
# ---------------------------------------------------------------------- #
class AffectedOwnerCache:
    """Vertex-level result cache invalidated by affected-owner sets.

    One entry per compiled plan group: the fused query's full result
    vectors (``{agg: [n]}``) plus a per-vertex validity mask.
    :meth:`on_update` clears ONLY the affected owners' bits — their
    windows are the only ones whose membership changed, so every other
    cached aggregate is still exact; groups without incremental state
    (no index to bound the blast radius) are dropped wholesale.

    Reads and writes are version-gated: entries are valid at
    :attr:`version` (advanced by ``on_update``), and a reader or writer
    pinned at any other version bypasses the cache instead of polluting
    it — that is what lets the serving layer keep reads pinned behind the
    write head (``auto_flip=False``) without ever serving stale hits.
    """

    def __init__(self, obs=None):
        self.version = 0
        self._entries: Dict[int, Dict] = {}
        self.hits = 0
        self.misses = 0
        self.invalidated = 0  # per-vertex invalidations applied
        self.full_drops = 0  # whole entries dropped (stateless groups)
        obs = obs if obs is not None else _obs.get_registry()
        self._m_events = obs.counter(
            "repro_cache_events_total",
            "AffectedOwnerCache group-read/invalidation events",
            labels=("event",))

    def bind(self, session) -> None:
        """Called by :meth:`Session.attach_cache`."""
        self.version = session.version

    # ------------------------------- reads ---------------------------- #
    def get_group(self, gi: int, version: int):
        """Full vectors of group ``gi`` if entirely valid at ``version``."""
        e = self._entries.get(gi)
        if version != self.version or e is None or not e["valid_all"]:
            self.misses += 1
            self._m_events.labels("miss").inc()
            return None
        self.hits += 1
        self._m_events.labels("hit").inc()
        return {a: v.copy() for a, v in e["vectors"].items()}

    def get_point(self, gi: int, agg: str, vertex: int, version: int):
        """Cached aggregate of one vertex, or None on miss/stale.

        Not counted in :attr:`hits`/:attr:`misses` — those track
        full-vector group reads (refresh dedup); a point miss always falls
        through to a group read, so counting both would double-book it.
        The service keeps its own point-level counters.
        """
        e = self._entries.get(gi)
        if version != self.version or e is None or not e["valid"][vertex]:
            return None
        return e["vectors"][agg][vertex]

    # ------------------------------- writes --------------------------- #
    def put_group(self, gi: int, version: int, vectors: Dict) -> None:
        if version != self.version:
            return  # writer pinned behind the head: do not pollute
        vecs = {a: np.array(v) for a, v in vectors.items()}
        n = len(next(iter(vecs.values())))
        self._entries[gi] = {
            "vectors": vecs,
            "valid": np.ones(n, dtype=bool),
            "valid_all": True,
        }

    def on_update(self, version: int, owner_map: Dict) -> None:
        """Advance to ``version``.  ``owner_map[gi]`` is the group's
        affected-owner array, or None when the group has no incremental
        state (nothing bounds its staleness — drop the entry).

        The version advances *first*: a concurrent reader that computed a
        group vector at the old version must find its ``put_group``
        rejected by the gate rather than landing between the invalidation
        sweep and the bump (which would resurrect a stale vector at the
        new version — the lost-invalidation race).  No reader can be
        pinned *at* the new version yet: the serving layer flips only
        after this returns.
        """
        self.version = version
        for gi, owners in owner_map.items():
            e = self._entries.get(gi)
            if e is None:
                continue
            if owners is None:
                del self._entries[gi]
                self.full_drops += 1
                self._m_events.labels("drop").inc()
                continue
            owners = np.asarray(owners, np.int64)
            e["valid"][owners] = False
            e["valid_all"] = bool(e["valid"].all())
            self.invalidated += int(owners.size)
            self._m_events.labels("invalidate").inc(int(owners.size))

    # ------------------------------------------------------------------ #
    def valid_fraction(self, gi: int) -> float:
        e = self._entries.get(gi)
        return float(e["valid"].mean()) if e is not None else 0.0

    @property
    def stats(self) -> Dict:
        total = self.hits + self.misses
        return {
            "version": self.version,
            "entries": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / max(total, 1),
            "invalidated": self.invalidated,
            "full_drops": self.full_drops,
        }


# ---------------------------------------------------------------------- #
#  WindowService
# ---------------------------------------------------------------------- #
class WindowService:
    """Micro-batched, versioned, cached front end over a Session.

    ``bucket`` fixes the padded batch size of coalesced explicit-values
    launches (the executable-reuse contract); ``auto_flip`` publishes every
    update to readers immediately (turn it off to pin readers at a version
    while a burst of updates lands, then :meth:`flip` once).

    Request model: :meth:`submit` enqueues and returns a :class:`Ticket`;
    :meth:`flush` serves everything pending against the active snapshot;
    :meth:`query` is submit+flush for one-call convenience.  A request
    names a compiled spec (index or the ``QuerySpec`` itself), optionally a
    ``vertex`` (point read) and optionally an explicit ``values`` vector
    (evaluate the spec's window under substitute attribute values — the
    classic serving pattern where each caller brings its own features).

    Flushes are exception-safe: a fused launch that raises fails exactly
    the tickets it was serving (error recorded on each
    :class:`Ticket`), the queue is already detached so nothing is
    stranded, the version-gated cache never holds partial results, and
    the next flush starts clean.
    """

    def __init__(self, session: Session, bucket: int = 8,
                 auto_flip: bool = True, use_cache: bool = True,
                 obs=None, tracer=None, now_fn=None,
                 flight_capacity: int = 256):
        self.session = session
        self.bucket = int(bucket)
        assert self.bucket >= 1
        self.auto_flip = auto_flip
        self.obs = obs if obs is not None else _obs.get_registry()
        self.tracer = tracer if tracer is not None else _obs.get_tracer()
        self.now = now_fn if now_fn is not None else time.perf_counter
        self.cache = AffectedOwnerCache(obs=self.obs) if use_cache else None
        if self.cache is not None:
            session.attach_cache(self.cache)
        self._active = session.snapshot()
        self._pending: List[Ticket] = []
        self._lock = threading.RLock()  # guards _pending + _rid
        self._flush_lock = threading.Lock()  # serializes _serve bodies
        self._rid = 0
        self._spec_index = {s: i for i, s in enumerate(session.compiled.specs)}
        # telemetry (attribute counters stay; obs mirrors them with labels)
        self.flushes = 0
        self.batched_launches = 0
        self.padded_rows = 0
        self.served = 0
        self.failed = 0
        self.point_hits = 0
        self.point_misses = 0
        self.slo = SLOTracker(self.obs)
        # flight recorder: always on (a crash artifact must exist for
        # crashes that never scheduled an instrumented run); one dict +
        # deque append per event keeps it inside the <5% obs budget
        self.flight = FlightRecorder(capacity=flight_capacity)
        #: events captured at the moment a ticket last failed (the
        #: automatic dump; None until a failure happens)
        self.last_flight_record: Optional[List[Dict]] = None
        #: shadow auditor sampling served tickets (None = auditing off);
        #: see :meth:`attach_auditor`
        self.auditor = None
        self._m_flushes = self.obs.counter(
            "repro_flushes_total", "queue flushes by trigger",
            labels=("reason",))
        self._m_launches = self.obs.counter(
            "repro_batched_launches_total",
            "padded run_many device launches")
        self._m_padded = self.obs.counter(
            "repro_padded_rows_total", "pad rows in batched launches")
        self._m_point = self.obs.counter(
            "repro_point_reads_total", "point reads through the result cache",
            labels=("event",))
        self._m_flush_size = self.obs.histogram(
            "repro_flush_size_records", "tickets served per flush",
            buckets=_obs.DEFAULT_SIZE_BUCKETS)
        self._m_updates = self.obs.counter(
            "repro_service_updates_total", "update batches streamed in")
        self._m_flips = self.obs.counter(
            "repro_flips_total", "snapshot publishes to readers")

    # ------------------------------------------------------------------ #
    @property
    def version(self) -> int:
        """The pinned read version (what queries answer at)."""
        return self._active.version

    @property
    def head_version(self) -> int:
        """The write head (latest applied update)."""
        return self.session.version

    # ------------------------------------------------------------------ #
    def _resolve(self, spec) -> int:
        if isinstance(spec, (int, np.integer)):
            if not 0 <= int(spec) < len(self.session.compiled.specs):
                raise IndexError(f"spec index {spec} out of range")
            return int(spec)
        if not isinstance(spec, QuerySpec):
            raise TypeError(f"spec must be an int index or QuerySpec, "
                            f"got {spec!r}")
        if spec not in self._spec_index:
            raise KeyError(
                f"{spec} is not compiled into this session; compiled specs: "
                f"{list(self.session.compiled.specs)}"
            )
        return self._spec_index[spec]

    def _make_ticket(self, spec, vertex: Optional[int], values,
                     request_class: Optional[RequestClass] = None) -> Ticket:
        """Validate and build (but do not enqueue) one request.

        Everything is validated here, not at flush time — one malformed
        request must fail its own submit, never poison a whole coalesced
        flush of other callers' tickets."""
        si = self._resolve(spec)
        n = self.session.graph.n
        if vertex is not None:
            vertex = int(vertex)
            if not 0 <= vertex < n:
                raise IndexError(f"vertex {vertex} out of range [0, {n})")
        if values is not None:
            # f32 conversion here: a non-numeric vector must fail its own
            # submit, not blow up mid-flush (the executors cast to f32
            # anyway, so results are unchanged).  np.array (not asarray)
            # so a caller reusing one scratch buffer between submit and
            # flush cannot mutate an already-queued request.
            values = np.array(values, np.float32)
            if values.shape != (n,):
                raise ValueError(
                    f"per-request values must have shape ({n},), "
                    f"got {values.shape}"
                )
        now = self.now()
        deadline = (now + self._delay_s(request_class)
                    if request_class is not None else None)
        with self._lock:
            rid = self._rid
            self._rid += 1
        t = Ticket(
            rid=rid, spec_index=si, vertex=vertex, values=values,
            submitted_s=now, request_class=request_class,
            deadline_s=deadline,
        )
        # detached span: the ticket lifecycle crosses threads (submitted
        # here, finished by whichever flush serves it)
        t._span = self.tracer.start_span(
            "request", cat="ticket", rid=rid,
            cls=t.class_name, point=vertex is not None)
        self.flight.record("admit", rid=rid, cls=t.class_name,
                           point=vertex is not None,
                           version=self._active.version)
        return t

    def _delay_s(self, request_class: RequestClass) -> float:
        """Scheduling delay for one class, in seconds.  The base service
        uses the declared ``max_delay_ms``; the async tier may run a
        tighter *effective* delay under SLO-controller pressure (never a
        looser one — the declared deadline is a hard bound)."""
        return request_class.max_delay_ms / 1e3

    def attach_auditor(self, auditor) -> "WindowService":
        """Attach a :class:`~repro.obs.audit.ShadowAuditor`: every flush
        offers its served tickets for sampling (the auditor re-evaluates
        asynchronously; a full audit queue drops samples, never blocking
        serving).  Call ``auditor.start()`` separately."""
        self.auditor = auditor
        auditor.bind(self)
        return self

    def submit(self, spec, vertex: Optional[int] = None,
               values=None) -> Ticket:
        """Enqueue one request; returns its (unfilled) :class:`Ticket`."""
        t = self._make_ticket(spec, vertex, values)
        with self._lock:
            self._pending.append(t)
        return t

    def query(self, spec, vertex: Optional[int] = None, values=None):
        """Submit + flush; returns the result directly (raises the
        recorded error if the serving launch failed)."""
        t = self.submit(spec, vertex=vertex, values=values)
        self.flush()
        return t.get(timeout=0)

    # ------------------------------------------------------------------ #
    def _serve_snapshot(self, view, gi: int, agg: str,
                        vertex: Optional[int], memo: Dict):
        """Current-attribute read through the affected-owner cache.

        ``memo`` holds group vectors already computed *this flush*: when
        the versioned cache cannot serve (``use_cache=False``, or a reader
        pinned behind the write head bypassing it), N point reads of one
        group still cost one fused launch, not N.  A failed group launch
        poisons the memo slot with its exception, so later tickets of the
        same group fail fast instead of re-raising from a fresh launch.
        """
        if self.cache is not None and vertex is not None:
            hit = self.cache.get_point(gi, agg, vertex, view.version)
            if hit is not None:
                self.point_hits += 1
                self._m_point.labels("hit").inc()
                return hit, True
            self.point_misses += 1
            self._m_point.labels("miss").inc()
        # miss (or full read): one fused launch refreshes the whole group
        # vector — in the cache (cache-aware run_group) and the flush memo
        out = memo.get(gi)
        if isinstance(out, BaseException):
            raise out
        if out is None:
            try:
                out = memo[gi] = view.run_group(gi)
            except BaseException as e:
                memo[gi] = e
                raise
        vec = out[agg]
        # full reads copy at the ticket boundary: several tickets may share
        # one memo/cache vector, and a caller mutating its result must not
        # corrupt another caller's answer
        return (vec[vertex] if vertex is not None else vec.copy()), False

    def _take_pending(self) -> List[Ticket]:
        """Atomically detach the queue (so a raise can never strand it)."""
        with self._lock:
            pending, self._pending = self._pending, []
        return pending

    def flush(self, reason: str = "manual") -> List[Ticket]:
        """Serve every pending request against the active snapshot.

        Current-state requests (``values=None``) ride the affected-owner
        cache — point reads are O(1) hits in steady state.  Explicit-values
        requests coalesce per plan group into ``ceil(B / bucket)`` padded
        ``run_many`` launches, so requests for *different* aggregates of
        one (window, attr) group share a launch (they are channels of the
        same fused plan) and the [bucket, n] executable never retraces.

        ``reason`` labels the flush trigger in the metrics: "manual" here,
        "fill"/"deadline" when the continuous-batching front end decides.
        """
        with self._flush_lock:
            return self._serve(self._take_pending(), reason)

    def _serve(self, pending: List[Ticket],
               reason: str = "manual") -> List[Ticket]:
        if not pending:
            return pending
        with self.tracer.span("flush", cat="serve", reason=reason,
                              pending=len(pending)):
            return self._serve_inner(pending, reason)

    def _serve_inner(self, pending: List[Ticket],
                     reason: str) -> List[Ticket]:
        view = self._active
        groups = self.session.compiled.groups
        slots = self.session.compiled.spec_slots
        by_group: Dict[int, List[Ticket]] = {}
        memo: Dict[int, object] = {}  # group vectors (or poison) this flush
        for t in pending:
            gi, ai = slots[t.spec_index]
            if t.values is None:
                try:
                    t.result, t.cache_hit = self._serve_snapshot(
                        view, gi, groups[gi].aggs[ai], t.vertex, memo
                    )
                    t.version = view.version
                except BaseException as e:
                    t.error = e
            else:
                by_group.setdefault(gi, []).append(t)
        n = view.graph.n
        for gi, reqs in by_group.items():
            grp = groups[gi]
            # padding buys executable reuse only on the jitted batched
            # device paths; a host group would pay one full sequential
            # query per pad row for nothing.  artifacts[gi] holds one
            # (index, plan) pair per materialized term (composite windows
            # on the algebraic fast path carry several).
            pad = (
                self.session.registry.capability(grp.engine).device
                and any(p is not None for _, p in view.artifacts[gi])
            )
            for lo in range(0, len(reqs), self.bucket):
                chunk = reqs[lo: lo + self.bucket]
                rows_n = self.bucket if pad else len(chunk)
                vb = np.zeros((rows_n, n), np.float32)  # fixed bucket
                for row, t in enumerate(chunk):
                    vb[row] = t.values
                try:
                    with self.tracer.span("launch", cat="serve", group=gi,
                                          rows=rows_n, filled=len(chunk)):
                        out = view.run_group_many(gi, vb)
                except BaseException as e:
                    # fail exactly this chunk's tickets; other chunks (and
                    # other groups) still get served, and the queue was
                    # detached up front so the next flush starts clean
                    for t in chunk:
                        t.error = e
                    continue
                self.batched_launches += 1
                self.padded_rows += rows_n - len(chunk)
                self._m_launches.inc()
                self._m_padded.inc(rows_n - len(chunk))
                for row, t in enumerate(chunk):
                    _, ai = slots[t.spec_index]
                    vec = out[grp.aggs[ai]][row]
                    t.result = (vec[t.vertex] if t.vertex is not None
                                else np.asarray(vec))
                    t.version = view.version
        now = self.now()
        ok = 0
        for t in pending:
            t.latency_s = now - t.submitted_s
            if t.error is None:
                ok += 1
            target = (t.request_class.max_delay_ms / 1e3
                      if t.request_class is not None else None)
            self.slo.observe(
                t.class_name, t.latency_s, target,
                "ok" if t.error is None else "error")
            if t._span is not None:
                t._span.set(version=t.version, cache_hit=t.cache_hit,
                            ok=t.error is None).finish()
            t._finish()
        self.flushes += 1
        self.served += ok
        self.failed += len(pending) - ok
        self._m_flushes.labels(reason).inc()
        self._m_flush_size.observe(len(pending))
        self.flight.record("flush", reason=reason, tickets=len(pending),
                           served=ok, failed=len(pending) - ok,
                           version=view.version)
        if self.auditor is not None:
            try:
                self.auditor.observe_flush(view, pending)
            except Exception:
                pass  # auditing is evidence, never a serving failure
        if ok < len(pending):
            self._on_ticket_failure([t for t in pending
                                     if t.error is not None])
        return pending

    # ------------------------------------------------------------------ #
    def _on_ticket_failure(self, failed: List[Ticket]) -> None:
        """A ticket finished with an error: stamp failure events and dump
        the flight record automatically — the recent admit/shed/flush/
        patch/flip history IS the crash context."""
        for t in failed:
            self.flight.record(
                "failure", rid=t.rid, cls=t.class_name,
                error=type(t.error).__name__, detail=str(t.error)[:200])
        self.last_flight_record = self.flight.dump()

    def debug_report(self) -> Dict:
        """One structured dump of everything the service knows about
        itself: counters, serving-bucket padding waste, cache/SLO stats,
        staleness ratios, device-plan footprint, and the flight-recorder
        ring — the ANALYZE companion for the serving tier."""
        launched_rows = self.batched_launches * self.bucket
        report = {
            "stats": self.stats,
            "padding": {
                "bucket": self.bucket,
                "batched_launches": self.batched_launches,
                "padded_rows": self.padded_rows,
                "waste_fraction": (self.padded_rows / launched_rows
                                   if launched_rows else 0.0),
            },
            "staleness": self.session.staleness,
            "plan_footprint_bytes": int(
                self.session.explain().total_plan_nbytes),
            "flight": {
                "capacity": self.flight.capacity,
                "dropped": self.flight.dropped,
                "events": self.flight.dump(),
            },
            "last_flight_record": self.last_flight_record,
        }
        if self.auditor is not None:
            report["audit"] = self.auditor.stats
        return report

    # ------------------------------------------------------------------ #
    def update(self, batch) -> Dict:
        """Stream one UpdateBatch into the write head.

        Readers keep the active snapshot until :meth:`flip` (automatic
        when ``auto_flip``).  The session invalidates the attached cache
        for exactly the batch's affected-owner sets; version gating means
        a reader still pinned behind the head simply bypasses the cache
        rather than ever seeing version-v+1 data at version v.
        """
        with self.tracer.span("service.update", cat="update"):
            reports = self.session.update(batch)
            for key, rep in reports.items():
                self.flight.record(
                    "patch", key=key,
                    version=rep.get("version"),
                    plan_version=rep.get("plan_version"),
                    affected=int(np.size(rep.get("affected_owners", ()))),
                    reorganized=bool(rep.get("reorganized", False)))
            if self.auto_flip:
                self.flip()
        self._m_updates.inc()
        return reports

    def flip(self) -> int:
        """Atomically publish the newest version to readers: one reference
        swap of an immutable snapshot (no reader ever holds a half-patched
        plan — it holds either the old view or the new one)."""
        self._active = self.session.snapshot()
        self._m_flips.inc()
        self.flight.record("flip", version=self._active.version)
        return self._active.version

    # ------------------------------------------------------------------ #
    @property
    def stats(self) -> Dict:
        point = self.point_hits + self.point_misses
        out = {
            "served": self.served,
            "failed": self.failed,
            "flushes": self.flushes,
            "batched_launches": self.batched_launches,
            "padded_rows": self.padded_rows,
            "bucket": self.bucket,
            "active_version": self._active.version,
            "head_version": self.session.version,
            "point_hits": self.point_hits,
            "point_misses": self.point_misses,
            "point_hit_rate": self.point_hits / max(point, 1),
        }
        if self.cache is not None:
            out["cache"] = self.cache.stats
        out["recompiles"] = record_recompiles(self.obs)
        if self.obs.enabled:
            out["slo"] = self.slo.report()
        return out


# ---------------------------------------------------------------------- #
#  AsyncWindowService — continuous batching + durability
# ---------------------------------------------------------------------- #
class AsyncWindowService(WindowService):
    """Continuous-batching front end: deadline-driven background flusher,
    staleness-aware admission control, and WAL durability.

    * **Deadline-or-fill flushing** — a daemon flusher launches the
      pending queue when it holds a full ``bucket`` (fill flush) or when
      the earliest ticket's per-class deadline (``max_delay_ms``) expires
      (deadline flush).  At low load this bounds p99 latency by the
      deadline instead of by "whenever the bucket happens to fill".

    * **Backpressure + load shedding** — when the queue reaches the
      admission window, the lowest-priority *sheddable full-graph scan*
      is evicted first (its submitter sees :class:`LoadShedError`); point
      reads are never shed.  If the incoming request is itself the
      lowest-priority sheddable scan, *it* is rejected.  A non-sheddable
      request with nothing to evict waits (backpressure) for the flusher
      to drain.  The admission window shrinks as the session's staleness
      ratios approach the :class:`~repro.core.streaming.StalenessPolicy`
      thresholds (:meth:`pressure`) — a stale index is about to pay a
      reorganize, so the service trims its queue before that stall.

    * **Write-ahead logging** — :meth:`update` appends the batch to the
      WAL *before* applying it (append-before-apply): any state a reader
      could ever have observed is reconstructible by
      :meth:`Session.restore_from_wal`, and a follower tailing the log
      is a read replica.

    Use as a context manager (or :meth:`start`/:meth:`stop`).  Without
    ``start()`` the service degrades to the synchronous base behavior
    (submit + explicit :meth:`flush`), deadlines unenforced.
    """

    def __init__(self, session: Session, bucket: int = 8,
                 auto_flip: bool = True, use_cache: bool = True,
                 classes: Optional[Dict[str, RequestClass]] = None,
                 default_class: str = "interactive",
                 max_pending: int = 256,
                 wal: Union[None, str, "object"] = None,
                 wal_digests: bool = True, digest_results: bool = False,
                 policy=None, obs=None, tracer=None, now_fn=None):
        super().__init__(session, bucket=bucket, auto_flip=auto_flip,
                         use_cache=use_cache, obs=obs, tracer=tracer,
                         now_fn=now_fn)
        #: stamp a per-version content digest into the WAL after every
        #: update (the replica self-check channel); ``digest_results``
        #: additionally folds the served result vectors in
        self.wal_digests = bool(wal_digests)
        self.digest_results = bool(digest_results)
        self.classes = dict(DEFAULT_REQUEST_CLASSES)
        if classes:
            self.classes.update(classes)
        self.default_class = default_class
        self.max_pending = int(max_pending)
        assert self.max_pending >= self.bucket
        #: SLO-controller overrides: per-class *effective* scheduling delay
        #: in ms, clamped to ``(0, declared max_delay_ms]`` at use time
        self.class_delay_ms: Dict[str, float] = {}
        #: fill trigger (queue depth that launches immediately) in
        #: ``[1, bucket]`` — the controller trades launch occupancy for
        #: latency; the compiled ``[bucket, n]`` executor shape never moves
        self.fill_threshold = self.bucket
        if wal is not None and not hasattr(wal, "append"):
            from repro.serve.wal import WriteAheadLog

            wal = WriteAheadLog(wal, obs=self.obs)
        self.wal = wal
        if policy is None:
            from repro.core.streaming import StalenessPolicy

            policy = StalenessPolicy()
        self.policy = policy
        self._cv = threading.Condition(self._lock)
        self._update_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stopping = False
        self._drain = True
        # telemetry
        self.shed = 0
        self.deadline_flushes = 0
        self.fill_flushes = 0
        self.backpressure_waits = 0
        self._m_shed = self.obs.counter(
            "repro_shed_total", "tickets rejected/evicted by admission")
        self._m_backpressure = self.obs.counter(
            "repro_backpressure_waits_total",
            "submit waits for the flusher to drain")
        self._g_pressure = self.obs.gauge(
            "repro_service_pressure", "staleness pressure in [0, 1]")
        self._g_pending = self.obs.gauge(
            "repro_pending_requests", "queue depth after last submit/flush")

    # --------------------------- lifecycle ---------------------------- #
    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> "AsyncWindowService":
        if self.running:
            return self
        self._stopping = False
        self._thread = threading.Thread(target=self._flusher_loop,
                                        name="window-service-flusher",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the flusher; ``drain=True`` serves everything still
        pending first (``False`` fails the leftovers with
        :class:`LoadShedError`)."""
        if self._thread is None:
            return
        with self._cv:
            self._stopping = True
            self._drain = drain
            self._cv.notify_all()
        self._thread.join(timeout=30)
        self._thread = None
        if drain:
            self.flush()
        else:
            for t in self._take_pending():
                t.error = LoadShedError("service stopped without drain")
                self._drop_ticket(t)
                self.failed += 1
        if self.wal is not None:
            self.wal.sync()

    def __enter__(self) -> "AsyncWindowService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=True)

    def close(self) -> None:
        self.stop(drain=True)
        if self.wal is not None:
            self.wal.close()

    # --------------------------- admission ---------------------------- #
    def pressure(self) -> float:
        """Staleness pressure in [0, 1]: 0 = freshly reorganized, 1 = at
        the policy's reorganize thresholds.  The growth ratios start at
        1.0 (a fresh index *is* its own baseline), so they are normalized
        over the remaining headroom to the threshold."""
        pol = self.policy
        p = 0.0
        for s in self.session.staleness.values():
            p = max(
                p,
                (s["link_ratio"] - 1.0) / max(pol.max_link_ratio - 1.0, 1e-9),
                (s["block_ratio"] - 1.0)
                / max(pol.max_block_ratio - 1.0, 1e-9),
                s["garbage_ratio"] / max(pol.max_garbage_ratio, 1e-9),
            )
        p = float(min(max(p, 0.0), 1.0))
        self._g_pressure.set(p)
        return p

    def effective_max_pending(self) -> int:
        """Admission window: ``max_pending`` scaled down by staleness
        pressure (down to one bucket at full pressure)."""
        lo = self.bucket
        span = self.max_pending - lo
        return int(lo + span * (1.0 - self.pressure()))

    def _pick_victim(self, incoming: Ticket) -> Optional[Ticket]:
        """Lowest-priority sheddable full-graph scan among pending +
        incoming (ties: newest first, preserving FIFO among equals).
        Returns None when nothing is sheddable (point reads never are)."""
        candidates = [
            t for t in self._pending
            if t.vertex is None and t.request_class is not None
            and t.request_class.sheddable
        ]
        if (incoming.vertex is None and incoming.request_class is not None
                and incoming.request_class.sheddable):
            candidates.append(incoming)
        if not candidates:
            return None
        return min(candidates, key=lambda t: (t.priority, -t.rid))

    def _drop_ticket(self, t: Ticket) -> None:
        """Account one admission-control casualty (``t.error`` already
        holds the :class:`LoadShedError`) and release its waiter."""
        self._m_shed.inc()
        self.flight.record("shed", rid=t.rid, cls=t.class_name,
                           reason=str(t.error)[:200],
                           version=self._active.version)
        self.slo.observe(
            t.class_name, self.now() - t.submitted_s,
            (t.request_class.max_delay_ms / 1e3
             if t.request_class is not None else None),
            "shed")
        if t._span is not None:
            t._span.set(ok=False, shed=True).finish()
        t._finish()

    def submit(self, spec, vertex: Optional[int] = None, values=None,
               request_class: Union[None, str, RequestClass] = None
               ) -> Ticket:
        """Enqueue with admission control; wakes the flusher.

        Raises :class:`LoadShedError` if the request itself is shed at
        admission.  An evicted *pending* ticket gets the error recorded
        and its waiter released instead."""
        if request_class is None:
            request_class = ("point" if vertex is not None
                             else self.default_class)
        if isinstance(request_class, str):
            request_class = self.classes[request_class]
        t = self._make_ticket(spec, vertex, values, request_class)
        with self._cv:
            while len(self._pending) >= self.effective_max_pending():
                victim = self._pick_victim(t)
                if victim is t:
                    self.shed += 1
                    self.failed += 1
                    t.error = LoadShedError(
                        f"request shed at admission (queue "
                        f"{len(self._pending)}, pressure {self.pressure():.2f})"
                    )
                    self._drop_ticket(t)
                    raise t.error
                if victim is not None:
                    self._pending.remove(victim)
                    victim.error = LoadShedError(
                        "evicted by a higher-priority request under overload"
                    )
                    self._drop_ticket(victim)
                    self.shed += 1
                    self.failed += 1
                    continue
                # nothing sheddable (all point reads): backpressure —
                # wait for the flusher to drain.  Without a running
                # flusher nobody will drain for us: serve synchronously.
                if not self.running:
                    break
                self.backpressure_waits += 1
                self._m_backpressure.inc()
                self._cv.wait(timeout=0.01)
            self._pending.append(t)
            self._g_pending.set(len(self._pending))
            self._cv.notify_all()
        if not self.running:
            # no flusher thread: enforce fill/deadline synchronously so
            # the scheduling contract (and its counters) hold either way
            self.flush_if_due()
        return t

    # --------------------------- flushing ----------------------------- #
    def _delay_s(self, request_class: RequestClass) -> float:
        declared = request_class.max_delay_ms
        eff = self.class_delay_ms.get(request_class.name, declared)
        # the declared deadline is a ceiling, never raised; floor keeps a
        # runaway controller from busy-flushing every submit
        return min(max(eff, 0.05), declared) / 1e3

    def flush(self, reason: str = "manual") -> List[Ticket]:
        served = super().flush(reason)
        with self._cv:
            self._g_pending.set(len(self._pending))
            self._cv.notify_all()  # release backpressure waiters
        return served

    def _due_reason(self):
        """Why the queue should launch NOW — ``("fill" | "deadline", dl)``
        — or ``(None, dl)`` with the earliest deadline to sleep toward
        (``dl`` None when the queue is empty).  Caller holds the lock.

        This is the single scheduling decision, shared by the background
        flusher and the synchronous :meth:`flush_if_due` path, and it runs
        on the injected clock — tests drive it deterministically with a
        fake ``now_fn``.
        """
        if not self._pending:
            return None, None
        if len(self._pending) >= max(1, min(self.fill_threshold,
                                            self.bucket)):
            return "fill", None
        now = self.now()
        dl = min(t.deadline_s if t.deadline_s is not None else now + 0.05
                 for t in self._pending)
        if now >= dl:
            return "deadline", dl
        return None, dl

    def flush_if_due(self) -> List[Ticket]:
        """Synchronously flush iff the scheduling contract says so
        (bucket full, or the earliest deadline has passed on the injected
        clock).  Returns the served tickets ([] when not due)."""
        with self._cv:
            reason, _ = self._due_reason()
        if reason is None:
            return []
        return self._flush_reason(reason)

    def _flush_reason(self, reason: str) -> List[Ticket]:
        if reason == "fill":
            self.fill_flushes += 1
        else:
            self.deadline_flushes += 1
        return self.flush(reason)

    def _flusher_loop(self) -> None:
        self.tracer.name_thread()
        while True:
            reason = None
            with self._cv:
                while reason is None:
                    if self._stopping:
                        return  # stop() drains (or fails) the leftovers
                    reason, dl = self._due_reason()
                    if reason is not None:
                        break
                    if dl is None:
                        self._cv.wait(timeout=0.05)
                        continue
                    self._cv.wait(timeout=max(dl - self.now(), 1e-4))
            try:
                self._flush_reason(reason)
            except Exception:
                # _serve records per-ticket errors; anything escaping here
                # is a bug in the scheduler itself — keep the loop alive,
                # the queue was detached so no ticket is stranded
                pass

    # --------------------------- durability --------------------------- #
    def update(self, batch) -> Dict:
        """Append-before-apply: the batch is durable in the WAL before any
        reader can observe its effects, so replaying the log into a fresh
        session always reproduces (a prefix of) the served states."""
        with self._update_lock:
            if self.wal is not None:
                with self.tracer.span("wal.append", cat="update",
                                      version=self.session.version + 1):
                    self.wal.append(batch, version=self.session.version + 1)
                self.flight.record("wal_commit",
                                   version=self.session.version + 1,
                                   records=int(getattr(batch, "size", 0)))
            reports = super().update(batch)
            if self.wal is not None and self.wal_digests \
                    and hasattr(self.wal, "append_digest"):
                # the leader's per-version content attestation: written
                # after apply (the digest covers the *produced* state) but
                # still under the update lock, so record/digest pairs stay
                # adjacent and in version order in the log
                self.wal.append_digest(
                    self.session.digest(
                        include_results=self.digest_results),
                    version=self.session.version)
            return reports

    # ------------------------------------------------------------------ #
    @property
    def stats(self) -> Dict:
        out = super().stats
        out.update(
            shed=self.shed,
            deadline_flushes=self.deadline_flushes,
            fill_flushes=self.fill_flushes,
            backpressure_waits=self.backpressure_waits,
            pending=len(self._pending),
            max_pending=self.max_pending,
            effective_max_pending=self.effective_max_pending(),
            pressure=self.pressure(),
            running=self.running,
        )
        out["class_delay_ms"] = dict(self.class_delay_ms)
        out["fill_threshold"] = self.fill_threshold
        if self.wal is not None:
            out["wal"] = self.wal.stats
        return out


# ---------------------------------------------------------------------- #
#  SLOController: close the measure → adapt loop
# ---------------------------------------------------------------------- #
class SLOController:
    """Adapt an :class:`AsyncWindowService`'s batching knobs from measured
    per-class SLO attainment (ROADMAP direction 1's "adapt bucket sizes /
    ``max_delay_ms`` within declared bounds").

    Two knobs, both shape-safe (the compiled ``[bucket, n]`` executors are
    never retraced):

    * **per-class effective delay** (``service.class_delay_ms``) — how
      long the scheduler may hold a ticket for batching.  Tightening it
      flushes earlier, trading launch occupancy for latency.  Hard bounds:
      never above the class's *declared* ``max_delay_ms`` (the deadline
      contract is inviolable), never below ``min_delay_ms``.
    * **fill threshold** (``service.fill_threshold``) — the queue depth
      that triggers an immediate launch, in ``[1, bucket]``.  Lowered when
      the worst class is missing (smaller, sooner launches), restored
      toward ``bucket`` when every class is comfortably attaining.

    Decisions are **windowed and hysteretic**: each :meth:`step` scores
    the attainment of tickets finished *since the previous step* (deltas
    of :meth:`~repro.obs.slo.SLOTracker.counts`, so one bad cold-start
    window can't haunt the cumulative ratio), ignores windows with fewer
    than ``min_samples`` ok tickets, and only acts after ``hysteresis``
    consecutive agreeing windows — a single noisy window never flips the
    knobs.  Steps are multiplicative (``tighten_factor`` down,
    ``relax_factor`` up) so convergence is geometric from either side.

    Every decision is exported:
    ``repro_slo_controller_decisions_total{cls, action}`` (actions
    ``tighten`` / ``relax`` / ``hold``) and gauges
    ``repro_slo_effective_delay_ms{cls}`` / ``repro_slo_fill_threshold``.
    Drive it manually (:meth:`step` after each serving window — tests use
    this, wall-clock-free) or with :meth:`start` on a background thread.

    Requires a live metrics registry: under a ``NullRegistry`` the
    tracker records nothing, every window is empty, and the controller
    holds (by design — no evidence, no movement).
    """

    def __init__(self, service: AsyncWindowService, *,
                 target_attainment: float = 0.95,
                 min_delay_ms: float = 0.25,
                 tighten_factor: float = 0.6,
                 relax_factor: float = 1.25,
                 hysteresis: int = 2,
                 min_samples: int = 16,
                 adapt_fill: bool = True,
                 obs=None):
        assert 0.0 < target_attainment <= 1.0
        assert 0.0 < tighten_factor < 1.0 < relax_factor
        self.service = service
        self.target_attainment = float(target_attainment)
        self.min_delay_ms = float(min_delay_ms)
        self.tighten_factor = float(tighten_factor)
        self.relax_factor = float(relax_factor)
        self.hysteresis = max(int(hysteresis), 1)
        self.min_samples = max(int(min_samples), 1)
        self.adapt_fill = bool(adapt_fill)
        self._obs_explicit = obs
        self._last_counts: Dict[str, Dict[str, float]] = {}
        self._miss_streak: Dict[str, int] = {}
        self._ok_streak: Dict[str, int] = {}
        self.steps = 0
        self.decisions: List[Dict] = []
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ------------------------------------------------------------------ #
    @property
    def obs(self):
        """Registry resolved at call time (the obs re-enable rule)."""
        return (self._obs_explicit if self._obs_explicit is not None
                else _obs.get_registry())

    def _record(self, cls: str, action: str, delay_ms: float) -> None:
        reg = self.obs
        reg.counter("repro_slo_controller_decisions_total",
                    "SLO controller decisions", labels=("cls", "action")
                    ).labels(cls, action).inc()
        reg.gauge("repro_slo_effective_delay_ms",
                  "controller-effective scheduling delay",
                  labels=("cls",)).labels(cls).set(delay_ms)
        self.decisions.append({"step": self.steps, "cls": cls,
                               "action": action, "delay_ms": delay_ms})

    def effective_delay_ms(self, cls: str) -> float:
        declared = self.service.classes[cls].max_delay_ms
        return min(self.service.class_delay_ms.get(cls, declared), declared)

    def step(self) -> Dict[str, str]:
        """Score the window since the last step; move the knobs.  Returns
        ``{cls: action}`` for every declared class."""
        svc = self.service
        self.steps += 1
        actions: Dict[str, str] = {}
        worst_missing = False
        for cls_name, rc in svc.classes.items():
            cur = svc.slo.counts(cls_name)
            prev = self._last_counts.get(cls_name,
                                         {k: 0.0 for k in cur})
            self._last_counts[cls_name] = cur
            d_ok = cur["ok"] - prev["ok"]
            d_within = cur["within"] - prev["within"]
            eff = self.effective_delay_ms(cls_name)
            if d_ok < self.min_samples:
                actions[cls_name] = "hold"
                self._record(cls_name, "hold", eff)
                continue
            attainment = d_within / d_ok
            if attainment < self.target_attainment:
                worst_missing = True
                self._miss_streak[cls_name] = \
                    self._miss_streak.get(cls_name, 0) + 1
                self._ok_streak[cls_name] = 0
                if self._miss_streak[cls_name] >= self.hysteresis \
                        and eff > self.min_delay_ms:
                    new = max(eff * self.tighten_factor, self.min_delay_ms)
                    svc.class_delay_ms[cls_name] = new
                    self._miss_streak[cls_name] = 0
                    actions[cls_name] = "tighten"
                    self._record(cls_name, "tighten", new)
                    continue
            else:
                self._ok_streak[cls_name] = \
                    self._ok_streak.get(cls_name, 0) + 1
                self._miss_streak[cls_name] = 0
                if self._ok_streak[cls_name] >= self.hysteresis \
                        and eff < rc.max_delay_ms:
                    new = min(eff * self.relax_factor, rc.max_delay_ms)
                    svc.class_delay_ms[cls_name] = new
                    self._ok_streak[cls_name] = 0
                    actions[cls_name] = "relax"
                    self._record(cls_name, "relax", new)
                    continue
            actions[cls_name] = "hold"
            self._record(cls_name, "hold", eff)
        if self.adapt_fill:
            if worst_missing:
                svc.fill_threshold = max(1, svc.fill_threshold - 1)
            elif all(a in ("hold", "relax") for a in actions.values()):
                svc.fill_threshold = min(svc.bucket, svc.fill_threshold + 1)
            self.obs.gauge("repro_slo_fill_threshold",
                           "controller-effective fill trigger depth"
                           ).set(svc.fill_threshold)
        return actions

    # --------------------------- background ---------------------------- #
    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self, interval_s: float = 0.25) -> "SLOController":
        """Step continuously on a daemon thread until :meth:`stop`."""
        if not self.running:
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._loop, args=(float(interval_s),),
                name="slo-controller", daemon=True)
            self._thread.start()
        return self

    def stop(self, timeout: float = 10.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            self._thread = None

    def _loop(self, interval_s: float) -> None:
        while not self._stop.is_set():
            try:
                self.step()
            except Exception:
                pass  # a controller hiccup must never take serving down
            self._stop.wait(interval_s)

    @property
    def stats(self) -> Dict:
        return {
            "steps": self.steps,
            "running": self.running,
            "fill_threshold": self.service.fill_threshold,
            "class_delay_ms": {
                cls: self.effective_delay_ms(cls)
                for cls in self.service.classes},
            "decisions": self.decisions[-32:],
        }
