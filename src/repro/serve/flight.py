"""Bounded flight recorder for the serving tier.

A :class:`FlightRecorder` is a fixed-capacity ring of structured events —
admit / shed / flush / WAL-commit / patch / flip, each stamped with a
sequence number, a wall-relative timestamp, and the MVCC version in play —
so when a ticket fails the service can dump the *recent causal history*
(what was admitted, what was shed, which version flipped when) instead of
a bare exception.

Design constraints, in order:

* **cheap enough to stay on** — one dict build plus a ``deque.append``
  per event (appends are thread-safe under the GIL; no lock on the hot
  path), so the obs-overhead budget (< 5%) holds with the recorder
  enabled.  Unlike metrics/tracing it is *not* gated on ``obs.enable()``:
  a flight record is a crash artifact, and crashes do not schedule
  themselves for instrumented runs.
* **bounded** — ``capacity`` events, oldest evicted first; ``dropped``
  counts evictions so a dump says how much history it is missing.
* **structured** — events are plain dicts (JSON-able as-is) with a fixed
  vocabulary of ``event`` values; see :data:`EVENT_TYPES`.

``dump()`` returns the events newest-last; ``dump_json(path)`` writes
them to disk (the CI failure-artifact hook collects these).
"""

from __future__ import annotations

import json
import threading
import time
import weakref
from collections import deque
from typing import Dict, List, Optional

__all__ = ["FlightRecorder", "EVENT_TYPES", "all_recorders"]

#: the closed event vocabulary (keep docs/OBSERVABILITY.md in sync):
#: admit      — a request ticket entered the queue (cls, ticket, version)
#: shed       — admission control dropped a ticket (cls, reason)
#: flush      — a micro-batch launched (reason, tickets, served, failed)
#: wal_commit — an UpdateBatch was appended to the WAL (version, records)
#: patch      — index/plan state patched for one state key (key, version,
#:              affected, reorganized)
#: flip       — the serving head moved to a new MVCC version (version)
#: failure    — a ticket finished with an error (cls, error)
#: audit      — shadow-oracle mismatch on a served sample (spec, vertex,
#:              version, expected, got — hex bytes)
#: scrub      — at-rest CRC failure in a sealed WAL record (version,
#:              offset, detail)
#: divergence — follower digest disagreed with the leader's (version,
#:              wal_offset, detail)
EVENT_TYPES = ("admit", "shed", "flush", "wal_commit", "patch", "flip",
               "failure", "audit", "scrub", "divergence")

# every live recorder, for the CI failure-artifact hook: a test that never
# touched the service it built can still dump whatever flew this process
_RECORDERS: "weakref.WeakSet" = weakref.WeakSet()


def all_recorders() -> List["FlightRecorder"]:
    """Every live recorder in the process (weakly tracked)."""
    return list(_RECORDERS)


class FlightRecorder:
    """Fixed-capacity ring of structured serving events."""

    def __init__(self, capacity: int = 256, clock=time.perf_counter):
        self._events: deque = deque(maxlen=int(capacity))
        self._seq_lock = threading.Lock()
        self._seq = 0
        self._clock = clock
        self._epoch = clock()
        #: wall-clock time of the epoch: ``anchor_unix_s + t_s`` converts
        #: an event's relative stamp to Unix time, correlating flight
        #: records with trace and metric timestamps
        self.anchor_unix_s = time.time()
        self.dropped = 0
        _RECORDERS.add(self)

    @property
    def capacity(self) -> int:
        return self._events.maxlen

    def record(self, event: str, **fields) -> None:
        """Append one event.  ``event`` should be from :data:`EVENT_TYPES`
        (unknown types are recorded anyway — forward compatibility beats
        dropping evidence)."""
        with self._seq_lock:
            self._seq += 1
            seq = self._seq
            if len(self._events) == self._events.maxlen:
                self.dropped += 1
        ev = {"seq": seq, "t_s": self._clock() - self._epoch,
              "event": event}
        ev.update(fields)
        self._events.append(ev)

    def __len__(self) -> int:
        return len(self._events)

    def dump(self) -> List[Dict]:
        """The retained events, oldest first (each a JSON-able dict)."""
        return list(self._events)

    def dump_json(self, path) -> str:
        """Write ``{"dropped": N, "anchor_unix_s": T, "events": [...]}``
        to ``path`` (``anchor_unix_s + event["t_s"]`` is Unix time)."""
        with open(path, "w") as f:
            json.dump({"dropped": self.dropped,
                       "anchor_unix_s": self.anchor_unix_s,
                       "events": self.dump()},
                      f, indent=2, default=str)
        return str(path)

    def clear(self) -> None:
        self._events.clear()

    def tail(self, n: int = 32) -> List[Dict]:
        """The most recent ``n`` events (for inline failure dumps)."""
        evs = self.dump()
        return evs[-n:]
