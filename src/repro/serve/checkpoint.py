"""Snapshot checkpoints: bound WAL replay at recovery and enable rejoin.

A checkpoint is a pickle-free, CRC-attributed serialization of a
session's *graph* at one version (``GCKP1`` file format below).  The
graph is the only state that needs saving: indices, plans, and executors
are deterministic functions of it, and the repo's bit-identity invariant
guarantees that a session rebuilt from the checkpointed graph answers
exactly what the incrementally maintained original answered at that
version.  Recovery then becomes **checkpoint-load + bounded tail
replay** (:meth:`repro.core.api.Session.restore_from_wal` with
``checkpoint=``) instead of replaying the whole log, and sealed WAL
segments at or below the newest checkpoint become safe to truncate
(:meth:`repro.serve.wal.SegmentedWriteAheadLog.truncate_upto`).

File format (all little-endian)::

    header   := b"GCKP1\\n\\x00\\x00"                       (8 bytes)
    meta     := u32 len | crc32 | sorted-key JSON
    array    := u64 len | crc32 | raw bytes     (one per meta["arrays"])

``meta`` carries ``version``, the graph shape (``n``, ``directed``), the
array table (name, dtype, length — ``src``/``dst`` plus one entry per
vertex attribute), and the writer's :meth:`Session.digest` dict.  Every
section has its own crc32 so corruption is *attributed* ("checkpoint
digest mismatch" runbook in ``docs/SERVING.md``): a failing section CRC
raises :class:`CheckpointCorruptError`; a loaded graph whose recomputed
``graph_crc`` disagrees with the stamped digest raises
:class:`CheckpointDigestError` (the file is internally consistent but
does not describe the state it claims to).

Checkpoints are written atomically (tmp file + ``os.replace``) and named
``ckpt-{version:012d}.gckp`` so :func:`latest_checkpoint` can pick the
newest usable one by filename alone.
"""

from __future__ import annotations

import json
import os
import struct
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs as _obs
from repro.core.graph import Graph

__all__ = [
    "CheckpointCorruptError",
    "CheckpointDigestError",
    "checkpoint_filename",
    "latest_checkpoint",
    "list_checkpoints",
    "load_checkpoint",
    "save_checkpoint",
    "write_checkpoint",
]

_CKPT_MAGIC = b"GCKP1\n\x00\x00"
_META_HDR = struct.Struct("<II")   # len, crc32
_ARR_HDR = struct.Struct("<QI")    # len, crc32
_CKPT_PREFIX = "ckpt-"
_CKPT_SUFFIX = ".gckp"


class CheckpointCorruptError(ValueError):
    """A checkpoint section failed its CRC / framing — the file's bytes
    are damaged (storage rot, torn write).  Fall back to an older
    checkpoint or full WAL replay."""


class CheckpointDigestError(ValueError):
    """The checkpoint is internally consistent but its reconstructed
    graph does not match the stamped ``graph_crc`` — the writer and the
    file disagree about the state it describes.  Treat like a divergence
    finding: do not serve from it."""


def checkpoint_filename(version: int) -> str:
    """``ckpt-{version:012d}.gckp`` (lexical order == version order)."""
    return f"{_CKPT_PREFIX}{int(version):012d}{_CKPT_SUFFIX}"


def list_checkpoints(directory) -> List[Tuple[int, str]]:
    """``[(version, path)]`` for every checkpoint file, version order."""
    directory = os.fspath(directory)
    out: List[Tuple[int, str]] = []
    try:
        names = os.listdir(directory)
    except FileNotFoundError:
        return out
    for name in names:
        if not (name.startswith(_CKPT_PREFIX)
                and name.endswith(_CKPT_SUFFIX)):
            continue
        stem = name[len(_CKPT_PREFIX): -len(_CKPT_SUFFIX)]
        if stem.isdigit():
            out.append((int(stem), os.path.join(directory, name)))
    out.sort()
    return out


def latest_checkpoint(directory,
                      upto_version: Optional[int] = None
                      ) -> Optional[Tuple[int, str]]:
    """The newest ``(version, path)`` with ``version <= upto_version``
    (or the newest overall), or None when no checkpoint qualifies."""
    best = None
    for version, path in list_checkpoints(directory):
        if upto_version is not None and version > int(upto_version):
            continue
        best = (version, path)
    return best


# ---------------------------------------------------------------------- #
def _section(payload: bytes, hdr: struct.Struct) -> bytes:
    return hdr.pack(len(payload), zlib.crc32(payload) & 0xFFFFFFFF) + payload


def write_checkpoint(path, version: int, graph: Graph,
                     digest: Optional[Dict] = None) -> str:
    """Serialize ``graph`` at ``version`` to ``path`` (atomic).

    ``digest`` is the writer's :meth:`Session.digest` dict; when omitted,
    only the locally computed ``graph_crc`` is stamped.  Exposed below
    :func:`save_checkpoint` so tests can craft files with a deliberate
    digest (verification-path coverage)."""
    from repro.obs.audit import graph_crc

    path = os.fspath(path)
    arrays: List[Tuple[str, np.ndarray]] = [
        ("src", np.asarray(graph.src)), ("dst", np.asarray(graph.dst))]
    for name in sorted(graph.attrs):
        arrays.append((f"attr:{name}", np.asarray(graph.attrs[name])))
    if digest is None:
        digest = {"graph_crc": graph_crc(graph)}
    meta = {
        "version": int(version),
        "n": int(graph.n),
        "directed": bool(graph.directed),
        "n_edges": int(np.asarray(graph.src).shape[0]),
        "digest": digest,
        "arrays": [{"name": name, "dtype": str(a.dtype),
                    "shape": list(a.shape)} for name, a in arrays],
    }
    blob = [_CKPT_MAGIC,
            _section(json.dumps(meta, sort_keys=True).encode(), _META_HDR)]
    for _, a in arrays:
        blob.append(_section(np.ascontiguousarray(a).tobytes(), _ARR_HDR))
    tmp = f"{path}.tmp"
    with open(tmp, "wb") as f:
        f.write(b"".join(blob))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return path


def _read_section(data: bytes, off: int, hdr: struct.Struct,
                  what: str, path) -> Tuple[bytes, int]:
    if off + hdr.size > len(data):
        raise CheckpointCorruptError(
            f"{path!r}: truncated {what} header at byte {off}")
    length, crc = hdr.unpack_from(data, off)
    off += hdr.size
    end = off + length
    if end > len(data):
        raise CheckpointCorruptError(
            f"{path!r}: truncated {what} payload at byte {off}")
    payload = data[off:end]
    if zlib.crc32(payload) & 0xFFFFFFFF != crc:
        raise CheckpointCorruptError(
            f"{path!r}: {what} crc mismatch at byte {off}")
    return payload, end


def load_checkpoint(path, verify: bool = True) -> Tuple[int, Graph, Dict]:
    """Read a checkpoint: ``(version, graph, digest)``.

    Every section CRC is checked (:class:`CheckpointCorruptError` on
    damage); with ``verify`` (default) the rebuilt graph's ``graph_crc``
    must equal the stamped digest's (:class:`CheckpointDigestError`
    otherwise — "checkpoint digest mismatch" in the runbook)."""
    from repro.obs.audit import graph_crc

    path = os.fspath(path)
    with open(path, "rb") as f:
        data = f.read()
    if data[: len(_CKPT_MAGIC)] != _CKPT_MAGIC:
        raise CheckpointCorruptError(f"{path!r}: bad checkpoint magic")
    meta_raw, off = _read_section(data, len(_CKPT_MAGIC), _META_HDR,
                                  "meta", path)
    meta = json.loads(meta_raw.decode())
    arrays: Dict[str, np.ndarray] = {}
    for entry in meta["arrays"]:
        raw, off = _read_section(data, off, _ARR_HDR,
                                 f"array {entry['name']}", path)
        a = np.frombuffer(raw, dtype=np.dtype(entry["dtype"]))
        arrays[entry["name"]] = a.reshape(entry["shape"]).copy()
    attrs = {name[len("attr:"):]: a for name, a in arrays.items()
             if name.startswith("attr:")}
    graph = Graph(n=int(meta["n"]), src=arrays["src"], dst=arrays["dst"],
                  directed=bool(meta["directed"]), attrs=attrs)
    digest = meta.get("digest") or {}
    if verify and "graph_crc" in digest:
        got = graph_crc(graph)
        if got != digest["graph_crc"]:
            raise CheckpointDigestError(
                f"{path!r}: reconstructed graph_crc {got} != stamped "
                f"{digest['graph_crc']} (version {meta['version']})")
    return int(meta["version"]), graph, digest


def save_checkpoint(session, directory, obs=None) -> Tuple[int, str]:
    """Checkpoint a live session into ``directory``.

    Stamps the session's full :meth:`~repro.core.api.Session.digest`
    (graph + plan CRCs) and returns ``(version, path)``.  Idempotent per
    version (an existing file for the same version is replaced
    atomically with identical bytes)."""
    directory = os.fspath(directory)
    os.makedirs(directory, exist_ok=True)
    version = int(session.version)
    path = os.path.join(directory, checkpoint_filename(version))
    write_checkpoint(path, version, session.graph,
                     digest=session.digest())
    reg = obs if obs is not None else _obs.get_registry()
    reg.counter("repro_checkpoint_saves_total",
                "snapshot checkpoints written").inc()
    reg.gauge("repro_checkpoint_last_version",
              "version of the newest checkpoint written").set(version)
    return version, path
