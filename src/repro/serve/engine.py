"""Batched serving engine (continuous-batching-lite).

Request lifecycle: enqueue -> batched prefill (padded to the bucket) ->
token-by-token batched decode against a preallocated KV cache -> detach on
EOS/max-tokens.  The same ``prefill``/``decode_step`` functions the
multi-pod dry-run lowers are used here, jit'd for the local device.

Scale posture: slots are a fixed-size batch (decode batch never reshapes,
so the compiled step is reused); the cache contract is zero-initialized
free space (see ``cache_update_add``).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # int32 [S]
    max_new: int = 16
    out: Optional[np.ndarray] = None


class ServeEngine:
    def __init__(self, params, cfg, module, max_seq: int = 256, slots: int = 8):
        """module: repro.models.transformer or .moe (prefill/decode_step)."""
        self.params = params
        self.cfg = cfg
        self.mod = module
        self.max_seq = max_seq
        self.slots = slots
        self._decode = jax.jit(
            lambda p, tok, kv, pos: module.decode_step(p, tok, kv, pos, cfg),
            static_argnames=("pos",),
        )
        self._prefill = jax.jit(lambda p, t: module.prefill(p, t, cfg))

    def generate(self, requests: List[Request], greedy: bool = True) -> Dict[int, np.ndarray]:
        """Batched generation for <= slots requests of equal prompt bucket."""
        assert len(requests) <= self.slots
        live = list(requests)
        plen = max(r.prompt.size for r in live)
        b = len(live)
        prompts = np.zeros((b, plen), np.int32)
        for i, r in enumerate(live):
            prompts[i, : r.prompt.size] = r.prompt
        kv, logits = self._prefill(self.params, jnp.asarray(prompts))
        # grow cache to max_seq (zero-initialized free space)
        pad = self.max_seq - plen
        kv = {k: jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
              for k, v in kv.items()}
        outs = [[] for _ in live]
        tok = jnp.argmax(logits, axis=-1)
        max_new = max(r.max_new for r in live)
        for step in range(max_new):
            for i in range(b):
                if step < live[i].max_new:
                    outs[i].append(int(tok[i]))
            pos = plen + step
            if pos >= self.max_seq - 1 or step == max_new - 1:
                break
            logits, kv = self._decode(self.params, tok, kv, pos)
            tok = jnp.argmax(logits, axis=-1)
        return {r.rid: np.array(o[: r.max_new], np.int32) for r, o in zip(live, outs)}
