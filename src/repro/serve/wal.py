"""Write-ahead log of :class:`~repro.core.updates.UpdateBatch`es.

Durability for the serving tier (and the transport for cheap read
replicas): the service appends every batch to the log *before* applying it
to the live :class:`~repro.core.api.Session` (append-before-apply), so any
state a reader could ever observe is reconstructible by replaying the log
into a fresh session — :meth:`repro.core.api.Session.restore_from_wal`.
A follower tailing the same file by byte offset is a read replica
(:class:`repro.serve.replica.ReadReplica`).

File format (all little-endian)::

    header  := b"GWAL1\\n\\x00\\x00"                      (8 bytes, once)
    record  := b"WREC" | version u64 | payload_len u64 | crc32 u32
               | payload
    digest  := b"WDIG" | version u64 | payload_len u64 | crc32 u32
               | payload
    payload := the UpdateBatch codec bytes
               (:func:`repro.core.updates.encode_update_batch`)
               for records; sorted-key JSON (the
               :func:`repro.obs.audit.session_digest` dict) for digests

``version`` is the session version the batch *produces* (monotonically
increasing).  The crc32 covers the payload only; readers stop cleanly at
the first truncated or checksum-failing record — a torn tail from a crash
mid-append loses at most the records not yet fsynced, never corrupts the
prefix.

Digest records (:meth:`WriteAheadLog.append_digest`) are the leader's
per-version content attestation: a follower recomputes its own digest
after applying record ``v`` and compares (:meth:`repro.serve.replica.
ReadReplica.poll`), attributing any divergence to the first bad version
and the digest record's byte offset.  :func:`read_wal_records` *skips*
digest records, so every pre-digest reader (replay, recovery, replicas
polling by offset) keeps working on logs with or without them;
:func:`scan_wal_entries` surfaces both record kinds with their byte
offsets.  :attr:`WriteAheadLog.synced_size` is the durable high-water
mark — everything below it is *sealed*, which is the region the
background scrubber (:class:`repro.obs.audit.WalScrubber`) sweeps for
at-rest CRC rot without ever mistaking an in-flight tail for corruption.

fsync policy is *batched* (group commit): ``append`` always writes through
to the OS (so process crashes lose nothing), and the file is fsynced once
every ``fsync_every`` appends or ``fsync_interval_s`` seconds — whichever
comes first — so a power failure loses at most one commit group.
``sync()`` forces it; ``close()`` syncs.
"""

from __future__ import annotations

import json
import os
import struct
import time
import zlib
from typing import Dict, Iterator, List, Optional, Tuple

from repro import obs as _obs
from repro.core.updates import (
    UpdateBatch,
    decode_update_batch,
    encode_update_batch,
)

_FILE_MAGIC = b"GWAL1\n\x00\x00"
_REC_MAGIC = b"WREC"
_DIG_MAGIC = b"WDIG"
_REC_HDR = struct.Struct("<4sQQI")  # magic, version, payload_len, crc32


class WriteAheadLog:
    """Append-only, crash-tolerant log of update batches.

    Opens (or creates) ``path`` for appending; an existing log is resumed
    — :attr:`last_version` is recovered from the valid record prefix so
    version numbering continues monotonically.
    """

    def __init__(self, path, fsync_every: int = 8,
                 fsync_interval_s: float = 0.05, obs=None):
        self.path = os.fspath(path)
        assert fsync_every >= 1
        self.fsync_every = int(fsync_every)
        self.fsync_interval_s = float(fsync_interval_s)
        obs = obs if obs is not None else _obs.get_registry()
        self._m_appends = obs.counter(
            "repro_wal_appends_total", "records appended")
        self._m_bytes = obs.counter(
            "repro_wal_bytes_total", "record bytes written")
        self._m_fsync = obs.histogram(
            "repro_wal_fsync_seconds", "fsync latency (group commit)")
        self._m_commit = obs.histogram(
            "repro_wal_commit_records", "appends per group commit",
            buckets=_obs.DEFAULT_SIZE_BUCKETS)
        self._m_torn = obs.counter(
            "repro_wal_torn_truncations_total",
            "torn tails truncated at resume")
        existing = os.path.exists(self.path) and os.path.getsize(self.path) > 0
        self.last_version: Optional[int] = None
        self.resumed_records = 0
        self.torn_truncations = 0
        if existing:  # resume: scan the valid prefix, truncate a torn tail
            records, end = read_wal_records(self.path)
            if records:
                self.last_version = records[-1][0]
            self.resumed_records = len(records)
            if end < os.path.getsize(self.path):
                with open(self.path, "r+b") as f:
                    f.truncate(end)
                self.torn_truncations = 1
                self._m_torn.inc()
        self._f = open(self.path, "ab")
        if not existing:
            self._f.write(_FILE_MAGIC)
            self._f.flush()
            os.fsync(self._f.fileno())
        self._unsynced = 0
        self._last_sync = time.perf_counter()
        #: durable high-water mark: byte size of the *sealed* region
        #: (everything below it has been fsynced — the scrubber's domain)
        self.synced_size = self._f.tell()
        # telemetry
        self.appends = 0
        self.digest_appends = 0
        self.fsyncs = 0
        self.bytes_written = 0
        self.last_fsync_s = 0.0  # duration of the most recent fsync

    # ------------------------------------------------------------------ #
    def append(self, batch: UpdateBatch, version: Optional[int] = None,
               sync: Optional[bool] = None) -> int:
        """Append one batch; returns its version.

        Must be called *before* the batch is applied to the session
        (append-before-apply).  ``sync=True`` forces an fsync for this
        record; ``sync=False`` defers it past the batching policy; the
        default applies the policy."""
        if version is None:
            version = (self.last_version or 0) + 1
        payload = encode_update_batch(batch)
        self._write_record(_REC_MAGIC, int(version), payload, sync)
        self.appends += 1
        self._m_appends.inc()
        self.last_version = int(version)
        return int(version)

    def append_digest(self, digest: Dict,
                      version: Optional[int] = None,
                      sync: Optional[bool] = None) -> int:
        """Append one content-digest record (``WDIG``) for ``version``.

        ``digest`` is the :func:`repro.obs.audit.session_digest` dict (any
        JSON-able dict works); the leader stamps one after publishing each
        version so followers can self-check after every poll.  Digest
        records do not advance :attr:`last_version` and are invisible to
        :func:`read_wal_records` / :meth:`replay` — they are attestation,
        not history."""
        if version is None:
            version = int(digest.get("version", self.last_version or 0))
        payload = json.dumps(digest, sort_keys=True).encode()
        self._write_record(_DIG_MAGIC, int(version), payload, sync)
        self.digest_appends += 1
        return int(version)

    def _write_record(self, magic: bytes, version: int, payload: bytes,
                      sync: Optional[bool]) -> None:
        rec = _REC_HDR.pack(magic, version, len(payload),
                            zlib.crc32(payload) & 0xFFFFFFFF) + payload
        self._f.write(rec)
        self._f.flush()  # through to the OS: ordered before the apply
        self.bytes_written += len(rec)
        self._m_bytes.inc(len(rec))
        self._unsynced += 1
        now = time.perf_counter()
        if sync or (sync is None and (
                self._unsynced >= self.fsync_every
                or now - self._last_sync >= self.fsync_interval_s)):
            self.sync()

    def sync(self) -> None:
        """Force the batched fsync (group commit boundary)."""
        if self._unsynced:
            t0 = time.perf_counter()
            os.fsync(self._f.fileno())
            self.last_fsync_s = time.perf_counter() - t0
            self._m_fsync.observe(self.last_fsync_s)
            self._m_commit.observe(self._unsynced)
            self.fsyncs += 1
            self._unsynced = 0
            self.synced_size = self._f.tell()
        self._last_sync = time.perf_counter()

    def close(self) -> None:
        if not self._f.closed:
            self.sync()
            self._f.close()

    def __enter__(self) -> "WriteAheadLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------ #
    def replay(self) -> Iterator[Tuple[int, UpdateBatch]]:
        """Iterate ``(version, batch)`` over the whole durable prefix."""
        self.sync()
        return iter(read_wal_records(self.path)[0])

    @property
    def stats(self) -> Dict:
        return {
            "path": self.path,
            "appends": self.appends,
            "digest_appends": self.digest_appends,
            "fsyncs": self.fsyncs,
            "bytes_written": self.bytes_written,
            "last_version": self.last_version,
            "unsynced": self._unsynced,
            "synced_size": self.synced_size,
            "records": self.appends,
            "bytes": self.bytes_written,
            "resumed_records": self.resumed_records,
            "torn_truncations": self.torn_truncations,
            "last_fsync_s": self.last_fsync_s,
        }


# ---------------------------------------------------------------------- #
def read_wal_records(
    path, offset: int = 0
) -> Tuple[List[Tuple[int, UpdateBatch]], int]:
    """Decode records from ``offset`` (0 = start, past the file header).

    Returns ``(records, end_offset)`` where ``records`` is a list of
    ``(version, batch)`` and ``end_offset`` is the byte position after the
    last *complete, checksum-valid* record — a replica polls by passing the
    previous call's ``end_offset`` back in, and a partially appended tail
    is simply retried on the next poll rather than treated as corruption.
    """
    with open(path, "rb") as f:
        data = f.read()
    off = int(offset)
    if off == 0:
        if len(data) < len(_FILE_MAGIC):
            return [], 0
        if data[: len(_FILE_MAGIC)] != _FILE_MAGIC:
            raise ValueError(f"{path!r} is not a WAL file (bad header)")
        off = len(_FILE_MAGIC)
    records: List[Tuple[int, UpdateBatch]] = []
    while off + _REC_HDR.size <= len(data):
        magic, version, length, crc = _REC_HDR.unpack_from(data, off)
        if magic not in (_REC_MAGIC, _DIG_MAGIC):
            break  # corrupt header: stop at the valid prefix
        end = off + _REC_HDR.size + length
        if end > len(data):
            break  # truncated tail (mid-append or torn write)
        payload = data[off + _REC_HDR.size: end]
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            break  # torn write inside the payload
        if magic == _REC_MAGIC:
            records.append((int(version), decode_update_batch(payload)))
        # digest records are attestation, not history: skip but advance
        off = end
    return records, off


def scan_wal_entries(path, offset: int = 0) -> Tuple[List[Dict], int]:
    """Decode *every* record kind from ``offset`` with byte attribution.

    Like :func:`read_wal_records` but surfaces digest records too.  Returns
    ``(entries, end_offset)`` where each entry is a dict with ``kind``
    (``"batch"`` or ``"digest"``), ``version``, ``offset`` (byte position
    of the record header — the attribution handle for divergence
    findings), and either ``batch`` (an
    :class:`~repro.core.updates.UpdateBatch`) or ``digest`` (the decoded
    JSON dict).  Stops at the first truncated / checksum-failing record,
    same as :func:`read_wal_records`.
    """
    with open(path, "rb") as f:
        data = f.read()
    off = int(offset)
    if off == 0:
        if len(data) < len(_FILE_MAGIC):
            return [], 0
        if data[: len(_FILE_MAGIC)] != _FILE_MAGIC:
            raise ValueError(f"{path!r} is not a WAL file (bad header)")
        off = len(_FILE_MAGIC)
    entries: List[Dict] = []
    while off + _REC_HDR.size <= len(data):
        magic, version, length, crc = _REC_HDR.unpack_from(data, off)
        if magic not in (_REC_MAGIC, _DIG_MAGIC):
            break
        end = off + _REC_HDR.size + length
        if end > len(data):
            break
        payload = data[off + _REC_HDR.size: end]
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            break
        if magic == _REC_MAGIC:
            entries.append({"kind": "batch", "version": int(version),
                            "offset": off,
                            "batch": decode_update_batch(payload)})
        else:
            entries.append({"kind": "digest", "version": int(version),
                            "offset": off,
                            "digest": json.loads(payload.decode())})
        off = end
    return entries, off


def replay_wal(path) -> Iterator[Tuple[int, UpdateBatch]]:
    """Iterate ``(version, batch)`` over a log file's valid prefix."""
    return iter(read_wal_records(path)[0])
