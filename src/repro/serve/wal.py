"""Write-ahead log of :class:`~repro.core.updates.UpdateBatch`es.

Durability for the serving tier (and the transport for cheap read
replicas): the service appends every batch to the log *before* applying it
to the live :class:`~repro.core.api.Session` (append-before-apply), so any
state a reader could ever observe is reconstructible by replaying the log
into a fresh session — :meth:`repro.core.api.Session.restore_from_wal`.
A follower tailing the same file by byte offset is a read replica
(:class:`repro.serve.replica.ReadReplica`).

File format (all little-endian)::

    header  := b"GWAL1\\n\\x00\\x00"                      (8 bytes, once)
    record  := b"WREC" | version u64 | payload_len u64 | crc32 u32
               | payload
    digest  := b"WDIG" | version u64 | payload_len u64 | crc32 u32
               | payload
    payload := the UpdateBatch codec bytes
               (:func:`repro.core.updates.encode_update_batch`)
               for records; sorted-key JSON (the
               :func:`repro.obs.audit.session_digest` dict) for digests

``version`` is the session version the batch *produces* (monotonically
increasing).  The crc32 covers the payload only; readers stop cleanly at
the first truncated or checksum-failing record — a torn tail from a crash
mid-append loses at most the records not yet fsynced, never corrupts the
prefix.

Digest records (:meth:`WriteAheadLog.append_digest`) are the leader's
per-version content attestation: a follower recomputes its own digest
after applying record ``v`` and compares (:meth:`repro.serve.replica.
ReadReplica.poll`), attributing any divergence to the first bad version
and the digest record's byte offset.  :func:`read_wal_records` *skips*
digest records, so every pre-digest reader (replay, recovery, replicas
polling by offset) keeps working on logs with or without them;
:func:`scan_wal_entries` surfaces both record kinds with their byte
offsets.  :attr:`WriteAheadLog.synced_size` is the durable high-water
mark — everything below it is *sealed*, which is the region the
background scrubber (:class:`repro.obs.audit.WalScrubber`) sweeps for
at-rest CRC rot without ever mistaking an in-flight tail for corruption.

fsync policy is *batched* (group commit): ``append`` always writes through
to the OS (so process crashes lose nothing), and the file is fsynced once
every ``fsync_every`` appends or ``fsync_interval_s`` seconds — whichever
comes first — so a power failure loses at most one commit group.
``sync()`` forces it; ``close()`` syncs.
"""

from __future__ import annotations

import json
import os
import struct
import time
import zlib
from typing import Dict, Iterator, List, Optional, Tuple

from repro import obs as _obs
from repro.core.updates import (
    UpdateBatch,
    decode_update_batch,
    encode_update_batch,
)

_FILE_MAGIC = b"GWAL1\n\x00\x00"
_REC_MAGIC = b"WREC"
_DIG_MAGIC = b"WDIG"
_REC_HDR = struct.Struct("<4sQQI")  # magic, version, payload_len, crc32


class WriteAheadLog:
    """Append-only, crash-tolerant log of update batches.

    Opens (or creates) ``path`` for appending; an existing log is resumed
    — :attr:`last_version` is recovered from the valid record prefix so
    version numbering continues monotonically.
    """

    def __init__(self, path, fsync_every: int = 8,
                 fsync_interval_s: float = 0.05, obs=None):
        self.path = os.fspath(path)
        assert fsync_every >= 1
        self.fsync_every = int(fsync_every)
        self.fsync_interval_s = float(fsync_interval_s)
        obs = obs if obs is not None else _obs.get_registry()
        self._m_appends = obs.counter(
            "repro_wal_appends_total", "records appended")
        self._m_bytes = obs.counter(
            "repro_wal_bytes_total", "record bytes written")
        self._m_fsync = obs.histogram(
            "repro_wal_fsync_seconds", "fsync latency (group commit)")
        self._m_commit = obs.histogram(
            "repro_wal_commit_records", "appends per group commit",
            buckets=_obs.DEFAULT_SIZE_BUCKETS)
        self._m_torn = obs.counter(
            "repro_wal_torn_truncations_total",
            "torn tails truncated at resume")
        existing = os.path.exists(self.path) and os.path.getsize(self.path) > 0
        self.last_version: Optional[int] = None
        self.resumed_records = 0
        self.torn_truncations = 0
        if existing:  # resume: scan the valid prefix, truncate a torn tail
            records, end = read_wal_records(self.path)
            if records:
                self.last_version = records[-1][0]
            self.resumed_records = len(records)
            if end < os.path.getsize(self.path):
                with open(self.path, "r+b") as f:
                    f.truncate(end)
                self.torn_truncations = 1
                self._m_torn.inc()
        self._f = open(self.path, "ab")
        # write the magic whenever the file is (or was truncated back to)
        # empty — a kill mid-header-write leaves a <8-byte file whose torn
        # tail IS the header, and resume must re-seed it
        if self._f.tell() == 0:
            self._f.write(_FILE_MAGIC)
            self._f.flush()
            os.fsync(self._f.fileno())
        self._unsynced = 0
        self._last_sync = time.perf_counter()
        #: durable high-water mark: byte size of the *sealed* region
        #: (everything below it has been fsynced — the scrubber's domain)
        self.synced_size = self._f.tell()
        # telemetry
        self.appends = 0
        self.digest_appends = 0
        self.fsyncs = 0
        self.bytes_written = 0
        self.last_fsync_s = 0.0  # duration of the most recent fsync

    # ------------------------------------------------------------------ #
    def append(self, batch: UpdateBatch, version: Optional[int] = None,
               sync: Optional[bool] = None) -> int:
        """Append one batch; returns its version.

        Must be called *before* the batch is applied to the session
        (append-before-apply).  ``sync=True`` forces an fsync for this
        record; ``sync=False`` defers it past the batching policy; the
        default applies the policy."""
        if version is None:
            version = (self.last_version or 0) + 1
        payload = encode_update_batch(batch)
        self._write_record(_REC_MAGIC, int(version), payload, sync)
        self.appends += 1
        self._m_appends.inc()
        self.last_version = int(version)
        return int(version)

    def append_digest(self, digest: Dict,
                      version: Optional[int] = None,
                      sync: Optional[bool] = None) -> int:
        """Append one content-digest record (``WDIG``) for ``version``.

        ``digest`` is the :func:`repro.obs.audit.session_digest` dict (any
        JSON-able dict works); the leader stamps one after publishing each
        version so followers can self-check after every poll.  Digest
        records do not advance :attr:`last_version` and are invisible to
        :func:`read_wal_records` / :meth:`replay` — they are attestation,
        not history."""
        if version is None:
            version = int(digest.get("version", self.last_version or 0))
        payload = json.dumps(digest, sort_keys=True).encode()
        self._write_record(_DIG_MAGIC, int(version), payload, sync)
        self.digest_appends += 1
        return int(version)

    def _write_record(self, magic: bytes, version: int, payload: bytes,
                      sync: Optional[bool]) -> None:
        rec = _REC_HDR.pack(magic, version, len(payload),
                            zlib.crc32(payload) & 0xFFFFFFFF) + payload
        self._f.write(rec)
        self._f.flush()  # through to the OS: ordered before the apply
        self.bytes_written += len(rec)
        self._m_bytes.inc(len(rec))
        self._unsynced += 1
        now = time.perf_counter()
        if sync or (sync is None and (
                self._unsynced >= self.fsync_every
                or now - self._last_sync >= self.fsync_interval_s)):
            self.sync()

    def sync(self) -> None:
        """Force the batched fsync (group commit boundary)."""
        if self._unsynced:
            t0 = time.perf_counter()
            os.fsync(self._f.fileno())
            self.last_fsync_s = time.perf_counter() - t0
            self._m_fsync.observe(self.last_fsync_s)
            self._m_commit.observe(self._unsynced)
            self.fsyncs += 1
            self._unsynced = 0
            self.synced_size = self._f.tell()
        self._last_sync = time.perf_counter()

    def close(self) -> None:
        if not self._f.closed:
            self.sync()
            self._f.close()

    def __enter__(self) -> "WriteAheadLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------ #
    def replay(self) -> Iterator[Tuple[int, UpdateBatch]]:
        """Iterate ``(version, batch)`` over the whole durable prefix."""
        self.sync()
        return iter(read_wal_records(self.path)[0])

    @property
    def stats(self) -> Dict:
        return {
            "path": self.path,
            "appends": self.appends,
            "digest_appends": self.digest_appends,
            "fsyncs": self.fsyncs,
            "bytes_written": self.bytes_written,
            "last_version": self.last_version,
            "unsynced": self._unsynced,
            "synced_size": self.synced_size,
            "records": self.appends,
            "bytes": self.bytes_written,
            "resumed_records": self.resumed_records,
            "torn_truncations": self.torn_truncations,
            "last_fsync_s": self.last_fsync_s,
        }


# ---------------------------------------------------------------------- #
def read_wal_records(
    path, offset: int = 0
) -> Tuple[List[Tuple[int, UpdateBatch]], int]:
    """Decode records from ``offset`` (0 = start, past the file header).

    Returns ``(records, end_offset)`` where ``records`` is a list of
    ``(version, batch)`` and ``end_offset`` is the byte position after the
    last *complete, checksum-valid* record — a replica polls by passing the
    previous call's ``end_offset`` back in, and a partially appended tail
    is simply retried on the next poll rather than treated as corruption.
    """
    with open(path, "rb") as f:
        data = f.read()
    off = int(offset)
    if off == 0:
        if len(data) < len(_FILE_MAGIC):
            return [], 0
        if data[: len(_FILE_MAGIC)] != _FILE_MAGIC:
            raise ValueError(f"{path!r} is not a WAL file (bad header)")
        off = len(_FILE_MAGIC)
    records: List[Tuple[int, UpdateBatch]] = []
    while off + _REC_HDR.size <= len(data):
        magic, version, length, crc = _REC_HDR.unpack_from(data, off)
        if magic not in (_REC_MAGIC, _DIG_MAGIC):
            break  # corrupt header: stop at the valid prefix
        end = off + _REC_HDR.size + length
        if end > len(data):
            break  # truncated tail (mid-append or torn write)
        payload = data[off + _REC_HDR.size: end]
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            break  # torn write inside the payload
        if magic == _REC_MAGIC:
            records.append((int(version), decode_update_batch(payload)))
        # digest records are attestation, not history: skip but advance
        off = end
    return records, off


def scan_wal_entries(path, offset: int = 0) -> Tuple[List[Dict], int]:
    """Decode *every* record kind from ``offset`` with byte attribution.

    Like :func:`read_wal_records` but surfaces digest records too.  Returns
    ``(entries, end_offset)`` where each entry is a dict with ``kind``
    (``"batch"`` or ``"digest"``), ``version``, ``offset`` (byte position
    of the record header — the attribution handle for divergence
    findings), and either ``batch`` (an
    :class:`~repro.core.updates.UpdateBatch`) or ``digest`` (the decoded
    JSON dict).  Stops at the first truncated / checksum-failing record,
    same as :func:`read_wal_records`.
    """
    with open(path, "rb") as f:
        data = f.read()
    off = int(offset)
    if off == 0:
        if len(data) < len(_FILE_MAGIC):
            return [], 0
        if data[: len(_FILE_MAGIC)] != _FILE_MAGIC:
            raise ValueError(f"{path!r} is not a WAL file (bad header)")
        off = len(_FILE_MAGIC)
    entries: List[Dict] = []
    while off + _REC_HDR.size <= len(data):
        magic, version, length, crc = _REC_HDR.unpack_from(data, off)
        if magic not in (_REC_MAGIC, _DIG_MAGIC):
            break
        end = off + _REC_HDR.size + length
        if end > len(data):
            break
        payload = data[off + _REC_HDR.size: end]
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            break
        if magic == _REC_MAGIC:
            entries.append({"kind": "batch", "version": int(version),
                            "offset": off,
                            "batch": decode_update_batch(payload)})
        else:
            entries.append({"kind": "digest", "version": int(version),
                            "offset": off,
                            "digest": json.loads(payload.decode())})
        off = end
    return entries, off


def replay_wal(path) -> Iterator[Tuple[int, UpdateBatch]]:
    """Iterate ``(version, batch)`` over a log file's valid prefix."""
    return iter(read_wal_records(path)[0])


# ---------------------------------------------------------------------- #
#  Segmented WAL: a directory of GWAL1 files named by base version
# ---------------------------------------------------------------------- #
_SEG_SUFFIX = ".wal"


class WalTruncatedError(RuntimeError):
    """A reader's cursor (or required history) points below the oldest
    retained segment — the records were truncated away.  Recover from a
    checkpoint (:mod:`repro.serve.checkpoint`) instead of the log."""


def segment_filename(base_version: int) -> str:
    """Segment file name for the segment whose first record is
    ``base_version`` (zero-padded so lexical order == version order)."""
    return f"{int(base_version):012d}{_SEG_SUFFIX}"


def list_segments(directory) -> List[Tuple[int, str]]:
    """``[(base_version, path)]`` for every segment file, version order."""
    directory = os.fspath(directory)
    out: List[Tuple[int, str]] = []
    try:
        names = os.listdir(directory)
    except FileNotFoundError:
        return out
    for name in names:
        if not name.endswith(_SEG_SUFFIX):
            continue
        stem = name[: -len(_SEG_SUFFIX)]
        if stem.isdigit():
            out.append((int(stem), os.path.join(directory, name)))
    out.sort()
    return out


def scan_segmented_entries(
    directory, cursor: Optional[Tuple[int, int]] = None
) -> Tuple[List[Dict], Tuple[int, int]]:
    """:func:`scan_wal_entries` across a segment directory.

    ``cursor`` is ``(segment_base, offset)`` — the resume handle a replica
    passes back in (``None`` starts at the oldest retained segment).  Each
    returned entry additionally carries ``"segment"`` (its segment's base
    version).  Segment-boundary rules:

    * a *sealed* segment (one with a successor) that scans clean to its
      end-of-file advances the cursor to ``(next_base, 0)``;
    * a sealed segment that stops early (torn/corrupt bytes mid-file) is
      **held**, never skipped: the cursor stays inside it so no records
      can be silently jumped over — the scrubber/health tier surfaces the
      corruption;
    * the last (active) segment behaves like the single-file scan: a
      partially appended tail is simply retried on the next call.

    Raises :class:`WalTruncatedError` when the cursor's segment no longer
    exists (truncated away) — the reader must rebuild from a checkpoint.
    """
    segs = list_segments(directory)
    if not segs:
        return [], (cursor or (0, 0))
    if cursor is None or cursor == (0, 0):
        cur_base, cur_off = segs[0][0], 0
    else:
        cur_base, cur_off = int(cursor[0]), int(cursor[1])
    bases = [b for b, _ in segs]
    if cur_base not in bases:
        raise WalTruncatedError(
            f"cursor segment {cur_base} not in retained segments "
            f"{bases[:3]}..{bases[-1:]} under {os.fspath(directory)!r}")
    entries: List[Dict] = []
    out_cursor = (cur_base, cur_off)
    for i in range(bases.index(cur_base), len(segs)):
        base, path = segs[i]
        start = cur_off if base == cur_base else 0
        if os.path.getsize(path) == 0:
            # mid-rotation kill: created but never seeded — nothing to
            # read, and nothing before it was skipped to get here
            out_cursor = (base, start)
            continue
        es, end = scan_wal_entries(path, start)
        for e in es:
            e["segment"] = base
        entries.extend(es)
        sealed = i < len(segs) - 1
        if sealed and end >= os.path.getsize(path):
            out_cursor = (segs[i + 1][0], 0)
        else:
            out_cursor = (base, end)
            if sealed:
                break  # torn sealed segment: hold, never skip
    return entries, out_cursor


def seek_segmented(directory, after_version: int) -> Tuple[int, int]:
    """Cursor positioned so the next *batch* record read has
    ``version > after_version`` — the bounded-tail entry point after a
    checkpoint restore.  Raises :class:`WalTruncatedError` when the needed
    history was truncated away."""
    segs = list_segments(directory)
    after_version = int(after_version)
    if not segs:
        if after_version > 0:
            raise WalTruncatedError(
                f"no segments under {os.fspath(directory)!r} but history "
                f"after version {after_version} was requested")
        return (0, 0)
    if segs[0][0] > after_version + 1:
        raise WalTruncatedError(
            f"oldest retained segment starts at version {segs[0][0]} but "
            f"history from {after_version + 1} was requested")
    idx = max(i for i, (b, _) in enumerate(segs) if b <= after_version + 1)
    base, path = segs[idx]
    es, end = scan_wal_entries(path)
    for e in es:
        if e["kind"] == "batch" and e["version"] > after_version:
            return (base, e["offset"])
    if idx < len(segs) - 1:
        return (segs[idx + 1][0], 0)
    return (base, end)


def read_segmented_records(
    directory, after_version: int = 0
) -> List[Tuple[int, UpdateBatch]]:
    """``(version, batch)`` across all retained segments with
    ``version > after_version`` (replay/recovery entry point)."""
    cursor = seek_segmented(directory, after_version)
    entries, _ = scan_segmented_entries(directory, cursor)
    return [(e["version"], e["batch"]) for e in entries
            if e["kind"] == "batch" and e["version"] > int(after_version)]


class SegmentedWriteAheadLog:
    """A WAL split into rotated ``GWAL1`` segments named by base version.

    Same append/digest/sync surface as :class:`WriteAheadLog` (the async
    service and scrubber consume either through duck typing), plus:

    * **rotation** — a new segment starts once the active one holds
      ``rotate_records`` records or ``rotate_bytes`` bytes (checked before
      each batch append, so a record and its digest always share a
      segment); sealed segments are complete by construction (the active
      file is synced before the new one is created);
    * **truncation** — :meth:`truncate_upto` deletes sealed segments whose
      entire version range is ``<= version``; callers must pick ``version
      = min(slowest live replica, newest checkpoint)`` so no reader's
      cursor and no recovery path is stranded;
    * **resume** — sealed segments are validated end-to-end and a torn one
      raises (history must never be silently skipped); only the *last*
      segment gets the single-file torn-tail truncation, and an empty
      trailing segment left by a kill mid-rotation is adopted as the
      active segment.
    """

    def __init__(self, directory, *, rotate_bytes: int = 1 << 20,
                 rotate_records: Optional[int] = None,
                 fsync_every: int = 8, fsync_interval_s: float = 0.05,
                 obs=None):
        self.directory = os.fspath(directory)
        os.makedirs(self.directory, exist_ok=True)
        self.rotate_bytes = int(rotate_bytes) if rotate_bytes else 0
        self.rotate_records = int(rotate_records) if rotate_records else 0
        self.fsync_every = int(fsync_every)
        self.fsync_interval_s = float(fsync_interval_s)
        self._obs_explicit = obs
        self.rotations = 0
        self.truncated_segments = 0
        # counters folded in from sealed (closed) segments
        self._sealed = {"appends": 0, "digest_appends": 0, "fsyncs": 0,
                        "bytes_written": 0, "resumed_records": 0,
                        "torn_truncations": 0}
        segs = list_segments(self.directory)
        for base, path in segs[:-1]:  # sealed: validate, never truncate
            if os.path.getsize(path) == 0:
                continue  # empty non-trailing segment: nothing to lose
            records, end = read_wal_records(path)
            if end < os.path.getsize(path):
                raise ValueError(
                    f"sealed WAL segment {path!r} is torn/corrupt at byte "
                    f"{end} — refusing to resume past missing history")
            self._sealed["resumed_records"] += len(records)
        if segs:
            active_base = segs[-1][0]
        else:
            active_base = 1
        self._active_base = active_base
        self._active = WriteAheadLog(
            os.path.join(self.directory, segment_filename(active_base)),
            fsync_every=self.fsync_every,
            fsync_interval_s=self.fsync_interval_s, obs=obs)
        if self._active.last_version is None and active_base > 1:
            # empty/fresh trailing segment: history continues from the
            # sealed predecessor (base = its last version + 1)
            self.last_version: Optional[int] = active_base - 1
        else:
            self.last_version = self._active.last_version

    # ------------------------------------------------------------------ #
    @property
    def obs(self):
        """Registry resolved at call time so rotation-created segments and
        truncation counters land in a registry enabled after construction."""
        return (self._obs_explicit if self._obs_explicit is not None
                else _obs.get_registry())

    @property
    def path(self) -> str:
        """The active segment's path (scrubber/debug compatibility)."""
        return self._active.path

    @property
    def synced_size(self) -> int:
        return self._active.synced_size

    @property
    def active_base(self) -> int:
        return self._active_base

    def segments(self) -> List[Tuple[int, str]]:
        return list_segments(self.directory)

    # ------------------------------------------------------------------ #
    def _should_rotate(self) -> bool:
        if self._active.appends == 0:
            return False  # never rotate an empty segment
        if self.rotate_records and self._active.appends >= self.rotate_records:
            return True
        if self.rotate_bytes and self._active._f.tell() >= self.rotate_bytes:
            return True
        return False

    def rotate(self, next_version: Optional[int] = None) -> str:
        """Seal the active segment and start a new one whose base is the
        next version to be appended.  Returns the new segment's path."""
        if next_version is None:
            next_version = (self.last_version or 0) + 1
        for k in self._sealed:
            self._sealed[k] += getattr(self._active, k)
        self._active.close()  # syncs: the sealed segment is complete
        self._active_base = int(next_version)
        self._active = WriteAheadLog(
            os.path.join(self.directory, segment_filename(next_version)),
            fsync_every=self.fsync_every,
            fsync_interval_s=self.fsync_interval_s,
            obs=self._obs_explicit)
        self.rotations += 1
        self.obs.counter("repro_wal_rotations_total",
                         "WAL segment rotations").inc()
        return self._active.path

    def append(self, batch: UpdateBatch, version: Optional[int] = None,
               sync: Optional[bool] = None) -> int:
        if version is None:
            version = (self.last_version or 0) + 1
        if self._should_rotate():
            self.rotate(next_version=int(version))
        v = self._active.append(batch, version=int(version), sync=sync)
        self.last_version = v
        return v

    def append_digest(self, digest: Dict, version: Optional[int] = None,
                      sync: Optional[bool] = None) -> int:
        # digests never trigger rotation: a record and its attestation
        # always land in the same segment
        if version is None:
            version = int(digest.get("version", self.last_version or 0))
        return self._active.append_digest(digest, version=int(version),
                                          sync=sync)

    def sync(self) -> None:
        self._active.sync()

    def close(self) -> None:
        self._active.close()

    def __enter__(self) -> "SegmentedWriteAheadLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------ #
    def replay(self) -> Iterator[Tuple[int, UpdateBatch]]:
        """``(version, batch)`` across every retained segment, in order."""
        self.sync()
        out: List[Tuple[int, UpdateBatch]] = []
        for _, path in self.segments():
            if os.path.getsize(path) == 0:
                continue
            out.extend(read_wal_records(path)[0])
        return iter(out)

    def truncate_upto(self, version: Optional[int]) -> List[Tuple[int, str]]:
        """Delete sealed segments whose entire version range is
        ``<= version``; the active segment is never deleted.  Returns the
        removed ``[(base, path)]``.

        Safety is the *caller's* contract: pass ``min(slowest live
        replica's applied version, newest checkpoint version)`` so every
        tailing cursor stays valid and checkpoint+tail recovery keeps a
        complete tail (see :meth:`repro.serve.cluster.ReplicaSet.truncate`).
        """
        if version is None:
            return []
        segs = list_segments(self.directory)
        removed: List[Tuple[int, str]] = []
        for i, (base, path) in enumerate(segs[:-1]):
            last_in_seg = segs[i + 1][0] - 1  # next base = its first
            if last_in_seg <= int(version):
                os.remove(path)
                removed.append((base, path))
        if removed:
            self.truncated_segments += len(removed)
            self.obs.counter(
                "repro_wal_segments_truncated_total",
                "sealed WAL segments deleted by retention").inc(len(removed))
        return removed

    @property
    def stats(self) -> Dict:
        segs = self.segments()
        out = dict(self._active.stats)
        for k, v in self._sealed.items():
            out[k] = out.get(k, 0) + v
        out.update(
            directory=self.directory,
            last_version=self.last_version,
            active_base=self._active_base,
            segments=len(segs),
            oldest_base=segs[0][0] if segs else None,
            rotations=self.rotations,
            truncated_segments=self.truncated_segments,
            records=out["appends"],
            bytes=out["bytes_written"],
        )
        return out
