"""Serving layer: batched LM request engine + window-analytics service.

* :class:`~repro.serve.engine.ServeEngine` — continuous-batching-lite over
  prefill/decode step functions (the LM side of the repo).
* :class:`~repro.serve.window_service.WindowService` — micro-batched,
  versioned, cached front end over a window-analytics
  :class:`~repro.core.api.Session` (point-vertex + full-graph traffic
  against a live update stream).
* :class:`~repro.serve.window_service.AsyncWindowService` — continuous
  batching on top: deadline-driven background flusher, staleness-aware
  backpressure/load shedding, and WAL durability (append-before-apply).
* :class:`~repro.serve.window_service.SLOController` — closes the SLO
  loop: adapts per-class effective delays and the fill threshold from
  measured attainment, within declared bounds, with hysteresis.
* :class:`~repro.serve.wal.WriteAheadLog` — crash-tolerant update log;
  :class:`~repro.serve.wal.SegmentedWriteAheadLog` rotates it into
  base-version-named segments (tailing cursors, safe truncation);
  :meth:`repro.core.api.Session.restore_from_wal` replays either.
* :mod:`~repro.serve.checkpoint` — pickle-free snapshot checkpoints so
  recovery is checkpoint-load + bounded tail replay.
* :class:`~repro.serve.replica.ReadReplica` — follower session tailing
  the WAL by byte offset or ``(segment, offset)`` cursor (pinned reads,
  explicit catch-up + flip, checkpoint rejoin).
* :class:`~repro.serve.cluster.ReplicaSet` /
  :class:`~repro.serve.cluster.WindowRouter` — the cluster tier: one
  writer + N auto-catch-up followers, freshness/load routing with MVCC
  pinning and failover, checkpoint + truncation policy.
* :class:`~repro.serve.flight.FlightRecorder` — bounded ring of
  structured serving events (admit/shed/flush/WAL-commit/patch/flip,
  plus audit/scrub/divergence findings), dumped automatically when a
  ticket fails.
* :class:`~repro.serve.health.HealthMonitor` /
  :class:`~repro.serve.health.HealthServer` — liveness/readiness state
  machine over pressure, lag, SLO, quorum, audit and scrub signals,
  served over stdlib HTTP (``/metrics`` ``/healthz`` ``/readyz``
  ``/debug``).
"""

from repro.serve.checkpoint import (  # noqa: F401
    CheckpointCorruptError,
    CheckpointDigestError,
    latest_checkpoint,
    list_checkpoints,
    load_checkpoint,
    save_checkpoint,
)
from repro.serve.cluster import (  # noqa: F401
    ReplicaFailedError,
    ReplicaSet,
    RoutingError,
    WindowRouter,
)
from repro.serve.engine import ServeEngine  # noqa: F401
from repro.serve.flight import FlightRecorder  # noqa: F401
from repro.serve.health import (  # noqa: F401
    HealthMonitor,
    HealthServer,
    all_monitors,
)
from repro.serve.replica import ReadReplica  # noqa: F401
from repro.serve.wal import (  # noqa: F401
    SegmentedWriteAheadLog,
    WalTruncatedError,
    WriteAheadLog,
    list_segments,
    read_segmented_records,
    read_wal_records,
    replay_wal,
    scan_segmented_entries,
    scan_wal_entries,
    seek_segmented,
)
from repro.serve.window_service import (  # noqa: F401
    AffectedOwnerCache,
    AsyncWindowService,
    DEFAULT_REQUEST_CLASSES,
    LoadShedError,
    RequestClass,
    SLOController,
    Ticket,
    WindowService,
)
