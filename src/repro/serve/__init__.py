"""Serving layer: batched LM request engine + window-analytics service.

* :class:`~repro.serve.engine.ServeEngine` — continuous-batching-lite over
  prefill/decode step functions (the LM side of the repo).
* :class:`~repro.serve.window_service.WindowService` — micro-batched,
  versioned, cached front end over a window-analytics
  :class:`~repro.core.api.Session` (point-vertex + full-graph traffic
  against a live update stream).
* :class:`~repro.serve.window_service.AsyncWindowService` — continuous
  batching on top: deadline-driven background flusher, staleness-aware
  backpressure/load shedding, and WAL durability (append-before-apply).
* :class:`~repro.serve.wal.WriteAheadLog` — crash-tolerant update log;
  :meth:`repro.core.api.Session.restore_from_wal` replays it.
* :class:`~repro.serve.replica.ReadReplica` — follower session tailing
  the WAL by byte offset (pinned reads, explicit catch-up + flip).
* :class:`~repro.serve.flight.FlightRecorder` — bounded ring of
  structured serving events (admit/shed/flush/WAL-commit/patch/flip,
  plus audit/scrub/divergence findings), dumped automatically when a
  ticket fails.
* :class:`~repro.serve.health.HealthMonitor` /
  :class:`~repro.serve.health.HealthServer` — liveness/readiness state
  machine over pressure, lag, SLO, audit and scrub signals, served over
  stdlib HTTP (``/metrics`` ``/healthz`` ``/readyz`` ``/debug``).
"""

from repro.serve.engine import ServeEngine  # noqa: F401
from repro.serve.flight import FlightRecorder  # noqa: F401
from repro.serve.health import (  # noqa: F401
    HealthMonitor,
    HealthServer,
    all_monitors,
)
from repro.serve.replica import ReadReplica  # noqa: F401
from repro.serve.wal import (  # noqa: F401
    WriteAheadLog,
    read_wal_records,
    replay_wal,
    scan_wal_entries,
)
from repro.serve.window_service import (  # noqa: F401
    AffectedOwnerCache,
    AsyncWindowService,
    DEFAULT_REQUEST_CLASSES,
    LoadShedError,
    RequestClass,
    Ticket,
    WindowService,
)
