"""Serving: batched request engine over prefill/decode step functions."""

from repro.serve.engine import ServeEngine  # noqa: F401
