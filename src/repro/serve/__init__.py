"""Serving layer: batched LM request engine + window-analytics service.

* :class:`~repro.serve.engine.ServeEngine` — continuous-batching-lite over
  prefill/decode step functions (the LM side of the repo).
* :class:`~repro.serve.window_service.WindowService` — micro-batched,
  versioned, cached front end over a window-analytics
  :class:`~repro.core.api.Session` (point-vertex + full-graph traffic
  against a live update stream).
"""

from repro.serve.engine import ServeEngine  # noqa: F401
from repro.serve.window_service import (  # noqa: F401
    AffectedOwnerCache,
    Ticket,
    WindowService,
)
